//! SIS — the naive Sequential Incoherence Selection of paper §III-A.
//!
//! Identical selection rule to oASIS but recomputes `W_k⁻¹` (pseudo-inverse)
//! and every score `Δᵢ = dᵢ − bᵢᵀ W⁺ bᵢ` from scratch each step: O(k³ + k²n)
//! per iteration. It exists as the correctness oracle for oASIS — the
//! accelerated update formulas (Eq. 5/6) must reproduce its selection
//! sequence exactly — and for the ablation bench (fig6 runtime panel).

use super::{ColumnOracle, ColumnSampler, SelectionTrace, TracedSampler};
use crate::linalg::{pinv_psd, Mat};
use crate::nystrom::NystromApprox;
use crate::util::{rng::Pcg64, timing::Stopwatch};
use crate::Result;

/// The naive SIS sampler (test oracle; O(ℓ·(ℓ³+ℓ²n)) total).
#[derive(Clone, Debug)]
pub struct Sis {
    pub max_cols: usize,
    pub init_cols: usize,
    pub tol: f64,
    pub seed: u64,
}

impl Sis {
    pub fn new(max_cols: usize, init_cols: usize, tol: f64, seed: u64) -> Sis {
        assert!(init_cols >= 1 && init_cols <= max_cols);
        Sis { max_cols, init_cols, tol, seed }
    }

    pub fn sample_traced(
        &self,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)> {
        let sw = Stopwatch::start();
        let n = oracle.n();
        let l = self.max_cols.min(n);
        let d = oracle.diag();
        let tol = super::effective_tol(self.tol, &d);
        // seed columns — must match Oasis for sequence-equality tests:
        // same RNG stream, same rejection rule.
        let mut rng = Pcg64::new(self.seed);
        let mut cols: Vec<Vec<f64>>;
        let mut lambda: Vec<usize>;
        loop {
            let cand = rng.sample_without_replacement(n, self.init_cols.min(l));
            let test_cols: Vec<Vec<f64>> =
                cand.iter().map(|&j| oracle.column(j)).collect();
            let w = w_from(&test_cols, &cand);
            match crate::linalg::inverse(&w) {
                Some(inv)
                    if inv.max_abs() * w.max_abs() <= 1e12
                        && (inv.max_abs() * w.max_abs()).is_finite() =>
                {
                    cols = test_cols;
                    lambda = cand;
                    break;
                }
                _ => continue,
            }
        }
        let mut trace = SelectionTrace::default();
        for &j in &lambda {
            trace.order.push(j);
            trace.cum_secs.push(sw.secs());
            trace.deltas.push(f64::NAN);
        }

        while lambda.len() < l {
            let k = lambda.len();
            // W⁺ from scratch
            let w = w_from(&cols, &lambda);
            let winv = pinv_psd(&w, 1e-12);
            // Δ for every candidate from scratch
            let mut best = usize::MAX;
            let mut best_abs = -1.0;
            for i in 0..n {
                if lambda.contains(&i) {
                    continue;
                }
                let b: Vec<f64> = cols.iter().map(|c| c[i]).collect();
                let wb = winv.matvec(&b);
                let quad: f64 = b.iter().zip(&wb).map(|(x, y)| x * y).sum();
                let delta = (d[i] - quad).abs();
                if delta > best_abs {
                    best_abs = delta;
                    best = i;
                }
            }
            if best_abs < tol {
                break;
            }
            cols.push(oracle.column(best));
            lambda.push(best);
            trace.order.push(best);
            trace.cum_secs.push(sw.secs());
            trace.deltas.push(best_abs);
            let _ = k;
        }

        // assemble
        let k = lambda.len();
        let mut c = Mat::zeros(n, k);
        for (t, col) in cols.iter().enumerate() {
            for i in 0..n {
                c.data[i * k + t] = col[i];
            }
        }
        let w = w_from(&cols, &lambda);
        let winv = pinv_psd(&w, 1e-12);
        Ok((
            NystromApprox { indices: lambda, c, winv, selection_secs: sw.secs() },
            trace,
        ))
    }
}

fn w_from(cols: &[Vec<f64>], lambda: &[usize]) -> Mat {
    let k = lambda.len();
    let mut w = Mat::zeros(k, k);
    for (ti, &i) in lambda.iter().enumerate() {
        for (tj, col) in cols.iter().enumerate() {
            *w.at_mut(ti, tj) = col[i];
        }
    }
    w
}

impl ColumnSampler for Sis {
    fn name(&self) -> &'static str {
        "SIS (naive)"
    }

    fn sample(&self, oracle: &dyn ColumnOracle) -> Result<NystromApprox> {
        self.sample_traced(oracle).map(|(a, _)| a)
    }
}

impl TracedSampler for Sis {
    fn sample_traced(
        &self,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)> {
        Sis::sample_traced(self, oracle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::Gaussian;
    use crate::sampling::oasis::{Oasis, Variant};
    use crate::sampling::ImplicitOracle;

    /// DESIGN.md invariant 3: the accelerated oASIS must reproduce the
    /// naive SIS selection sequence exactly.
    #[test]
    fn oasis_matches_sis_sequence() {
        let ds = two_moons(90, 0.05, 17);
        let kern = Gaussian::new(0.6);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let (_, sis_trace) = Sis::new(18, 3, 1e-12, 5).sample_traced(&oracle).unwrap();
        for variant in [Variant::PaperR, Variant::Incremental] {
            let (_, o_trace) = Oasis::new(18, 3, 1e-12, 5)
                .with_variant(variant)
                .sample_traced(&oracle)
                .unwrap();
            assert_eq!(
                sis_trace.order, o_trace.order,
                "variant {variant:?} diverged from naive SIS"
            );
        }
    }

    #[test]
    fn sis_exact_recovery() {
        let ds = crate::data::generators::gauss_2d_plus_3d(25, 25, 3);
        let g = crate::kernels::kernel_matrix(&ds, &crate::kernels::Linear);
        let oracle = crate::sampling::ExplicitOracle::new(&g);
        let (approx, _) = Sis::new(10, 1, 1e-8, 2).sample_traced(&oracle).unwrap();
        assert!(approx.k() <= 4);
        let err = crate::nystrom::relative_frobenius_error(&oracle, &approx);
        assert!(err < 1e-6, "err {err}");
    }
}
