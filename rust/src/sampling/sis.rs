//! SIS — the naive Sequential Incoherence Selection of paper §III-A.
//!
//! Identical selection rule to oASIS but recomputes `W_k⁻¹` (pseudo-inverse)
//! and every score `Δᵢ = dᵢ − bᵢᵀ W⁺ bᵢ` from scratch each step: O(k³ + k²n)
//! per iteration. It exists as the correctness oracle for oASIS — the
//! accelerated update formulas (Eq. 5/6) must reproduce its selection
//! sequence exactly — and for the ablation bench (fig6 runtime panel).

use super::session::{
    run_to_completion, SamplerSession, StepOutcome, StopReason, StoppingRule,
};
use super::{ColumnOracle, ColumnSampler, SelectionTrace, TracedSampler};
use crate::linalg::{pinv_psd, Mat};
use crate::nystrom::NystromApprox;
use crate::util::{rng::Pcg64, timing::Stopwatch};
use crate::Result;
use crate::bail;

/// The naive SIS sampler (test oracle; O(ℓ·(ℓ³+ℓ²n)) total).
#[derive(Clone, Debug)]
pub struct Sis {
    pub max_cols: usize,
    pub init_cols: usize,
    pub tol: f64,
    pub seed: u64,
}

impl Sis {
    pub fn new(max_cols: usize, init_cols: usize, tol: f64, seed: u64) -> Sis {
        assert!(init_cols >= 1 && init_cols <= max_cols);
        Sis { max_cols, init_cols, tol, seed }
    }

    /// Open a stepwise session (one from-scratch rescoring + selection per
    /// step). Seeding matches [`super::oasis::Oasis`] exactly — same RNG
    /// stream, same rejection rule — so sequence-equality tests hold.
    pub fn session<'a>(&self, oracle: &'a dyn ColumnOracle) -> Result<SisSession<'a>> {
        let sw = Stopwatch::start();
        let n = oracle.n();
        let l = self.max_cols.min(n);
        let d = oracle.diag();
        let tol = super::effective_tol(self.tol, &d);
        let mut rng = Pcg64::new(self.seed);
        let cols: Vec<Vec<f64>>;
        let lambda: Vec<usize>;
        loop {
            let cand = rng.sample_without_replacement(n, self.init_cols.min(l));
            let test_cols: Vec<Vec<f64>> =
                cand.iter().map(|&j| oracle.column(j)).collect();
            let w = w_from(&test_cols, &cand);
            match crate::linalg::inverse(&w) {
                Some(inv)
                    if inv.max_abs() * w.max_abs() <= 1e12
                        && (inv.max_abs() * w.max_abs()).is_finite() =>
                {
                    cols = test_cols;
                    lambda = cand;
                    break;
                }
                _ => continue,
            }
        }
        let mut trace = SelectionTrace::default();
        for &j in &lambda {
            trace.order.push(j);
            trace.cum_secs.push(sw.secs());
            trace.deltas.push(f64::NAN);
        }
        Ok(SisSession {
            oracle,
            n,
            d,
            tol,
            cols,
            trace,
            resid_sum: None,
            d_abs_sum: 0.0,
            exhausted: None,
            busy_secs: sw.secs(),
        })
    }

    /// Open a session warm-started from a previously selected index set
    /// (artifact warm start) — the same replay shape as
    /// [`Oasis::session_from_indices`](super::oasis::Oasis::session_from_indices):
    /// the first `init_cols` indices seed W₀ by direct inversion (the
    /// arithmetic a successful seed draw performs), and the remaining
    /// indices are *replayed* through the step arithmetic with the
    /// argmax replaced by the stored selection. SIS recomputes W⁺ and
    /// every Δ from scratch each step, so the replayed session's state
    /// (fetched columns, trace, residual sum) is bit-identical to the
    /// recording session's — given the same oracle and `init_cols` —
    /// and continued selection extends it exactly as an uninterrupted
    /// run would.
    ///
    /// Replay cost is the full O(k³ + k²n) per column that selection
    /// paid (this sampler is the naive correctness oracle). Errors
    /// cleanly when the indices repeat, fall out of range, or score
    /// below the tolerance mid-replay — the signature of an artifact
    /// that does not match this dataset/kernel.
    pub fn session_from_indices<'a>(
        &self,
        oracle: &'a dyn ColumnOracle,
        indices: &[usize],
    ) -> Result<SisSession<'a>> {
        let sw = Stopwatch::start();
        let n = oracle.n();
        if indices.is_empty() {
            bail!("warm start needs at least one stored index");
        }
        let mut seen = vec![false; n];
        for &j in indices {
            if j >= n {
                bail!("stored index {j} out of range (n = {n})");
            }
            if seen[j] {
                bail!("stored index {j} repeats");
            }
            seen[j] = true;
        }
        let l = self.max_cols.min(n).max(indices.len());
        let k0 = self.init_cols.min(l).min(indices.len());
        let d = oracle.diag();
        let tol = super::effective_tol(self.tol, &d);
        let cols: Vec<Vec<f64>> =
            indices[..k0].iter().map(|&j| oracle.column(j)).collect();
        let w = w_from(&cols, &indices[..k0]);
        match crate::linalg::inverse(&w) {
            Some(inv)
                if (inv.max_abs() * w.max_abs()).is_finite()
                    && inv.max_abs() * w.max_abs() <= 1e12 => {}
            _ => bail!(
                "the stored seed columns are singular on this dataset/kernel \
                 — artifact mismatch?"
            ),
        }
        let mut trace = SelectionTrace::default();
        for &j in &indices[..k0] {
            trace.order.push(j);
            trace.cum_secs.push(sw.secs());
            trace.deltas.push(f64::NAN);
        }
        let mut session = SisSession {
            oracle,
            n,
            d,
            tol,
            cols,
            trace,
            resid_sum: None,
            d_abs_sum: 0.0,
            exhausted: None,
            busy_secs: sw.secs(),
        };
        for &j in &indices[k0..] {
            session
                .force_select(j)
                .map_err(|e| e.wrap("warm-start replay"))?;
        }
        Ok(session)
    }

    pub fn sample_traced(
        &self,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)> {
        let mut session = self.session(oracle)?;
        run_to_completion(&mut session, &StoppingRule::budget(self.max_cols))?;
        let trace = session.trace().clone();
        let approx = session.snapshot()?;
        Ok((approx, trace))
    }
}

/// A paused naive-SIS run (see [`Sis::session`]).
pub struct SisSession<'a> {
    oracle: &'a dyn ColumnOracle,
    n: usize,
    d: Vec<f64>,
    tol: f64,
    /// fetched columns, in selection order.
    cols: Vec<Vec<f64>>,
    trace: SelectionTrace,
    /// Σ|Δ| over unselected candidates from the latest rescoring sweep.
    resid_sum: Option<f64>,
    d_abs_sum: f64,
    exhausted: Option<StopReason>,
    busy_secs: f64,
}

impl SamplerSession for SisSession<'_> {
    fn name(&self) -> &'static str {
        "SIS (naive)"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn indices(&self) -> &[usize] {
        &self.trace.order
    }

    fn trace(&self) -> &SelectionTrace {
        &self.trace
    }

    fn selection_secs(&self) -> f64 {
        self.busy_secs
    }

    /// Residual trace ratio from the most recent full rescoring sweep
    /// (`None` before the first adaptive step).
    fn error_estimate(&self) -> Option<f64> {
        let sum = self.resid_sum?;
        if self.d_abs_sum <= 0.0 {
            return Some(0.0);
        }
        Some(sum / self.d_abs_sum)
    }

    fn step(&mut self) -> Result<StepOutcome> {
        if let Some(reason) = self.exhausted {
            return Ok(StepOutcome::Exhausted(reason));
        }
        let sw = Stopwatch::start();
        if self.trace.order.len() >= self.n {
            self.exhausted = Some(StopReason::Exhausted);
            self.busy_secs += sw.secs();
            return Ok(StepOutcome::Exhausted(StopReason::Exhausted));
        }
        let (best, best_abs, _, sum_abs) = self.rescore(None);
        self.resid_sum = Some(sum_abs);
        if self.d_abs_sum == 0.0 {
            self.d_abs_sum = self.d.iter().map(|x| x.abs()).sum();
        }
        if best == usize::MAX {
            self.exhausted = Some(StopReason::Exhausted);
            self.busy_secs += sw.secs();
            return Ok(StepOutcome::Exhausted(StopReason::Exhausted));
        }
        if best_abs < self.tol {
            self.exhausted = Some(StopReason::ScoreBelowTol);
            self.busy_secs += sw.secs();
            return Ok(StepOutcome::Exhausted(StopReason::ScoreBelowTol));
        }
        self.cols.push(self.oracle.column(best));
        self.trace.order.push(best);
        self.trace.cum_secs.push(self.busy_secs + sw.secs());
        self.trace.deltas.push(best_abs);
        self.busy_secs += sw.secs();
        Ok(StepOutcome::Selected { index: best, score: best_abs })
    }

    fn snapshot(&self) -> Result<NystromApprox> {
        let lambda = self.trace.order.clone();
        let n = self.n;
        let k = lambda.len();
        let mut c = Mat::zeros(n, k);
        for (t, col) in self.cols.iter().enumerate() {
            for i in 0..n {
                c.data[i * k + t] = col[i];
            }
        }
        let w = w_from(&self.cols, &lambda);
        let winv = pinv_psd(&w, 1e-12);
        Ok(NystromApprox {
            indices: lambda,
            c,
            winv,
            selection_secs: self.busy_secs,
        })
    }
}

impl SisSession<'_> {
    /// One from-scratch rescoring sweep — W⁺ rebuilt, every unselected
    /// candidate's Δ recomputed — returning `(argmax index, argmax |Δ|,
    /// |Δ| at `target`, Σ|Δ|)`. The argmax index is `usize::MAX` (and
    /// the target Δ `NaN`) when no candidate matched. Shared by
    /// [`step`](SamplerSession::step) (argmax selection) and
    /// [`force_select`](SisSession::force_select) (warm-start replay),
    /// so both perform bit-identical arithmetic — the warm-resume
    /// guarantee depends on these never diverging.
    fn rescore(&self, target: Option<usize>) -> (usize, f64, f64, f64) {
        let lambda = &self.trace.order;
        let w = w_from(&self.cols, lambda);
        let winv = pinv_psd(&w, 1e-12);
        let mut best = usize::MAX;
        let mut best_abs = -1.0;
        let mut target_abs = f64::NAN;
        let mut sum_abs = 0.0;
        for i in 0..self.n {
            if lambda.contains(&i) {
                continue;
            }
            let b: Vec<f64> = self.cols.iter().map(|c| c[i]).collect();
            let wb = winv.matvec(&b);
            let quad: f64 = b.iter().zip(&wb).map(|(x, y)| x * y).sum();
            let delta = (self.d[i] - quad).abs();
            sum_abs += delta;
            if delta > best_abs {
                best_abs = delta;
                best = i;
            }
            if target == Some(i) {
                target_abs = delta;
            }
        }
        (best, best_abs, target_abs, sum_abs)
    }

    /// Warm-start replay: incorporate a *stored* selection instead of
    /// the argmax. Performs the same full [`rescore`](SisSession::rescore)
    /// sweep `step` performs — including the residual-sum bookkeeping —
    /// with only the argmax replaced by the given index, so the
    /// replayed session's state is bit-identical to the one that
    /// recorded the index.
    fn force_select(&mut self, best: usize) -> Result<()> {
        let sw = Stopwatch::start();
        if best >= self.n || self.trace.order.contains(&best) {
            bail!("stored index {best} is out of range or already selected");
        }
        let (_, _, delta_best, sum_abs) = self.rescore(Some(best));
        self.resid_sum = Some(sum_abs);
        if self.d_abs_sum == 0.0 {
            self.d_abs_sum = self.d.iter().map(|x| x.abs()).sum();
        }
        // `!(≥)` also catches a NaN score
        if !(delta_best >= self.tol) {
            bail!(
                "replaying stored index {best}: |Δ| = {delta_best:.3e} is \
                 below the selection tolerance — the artifact does not match \
                 this dataset/kernel"
            );
        }
        self.cols.push(self.oracle.column(best));
        self.trace.order.push(best);
        self.trace.cum_secs.push(self.busy_secs + sw.secs());
        self.trace.deltas.push(delta_best);
        self.busy_secs += sw.secs();
        Ok(())
    }
}

fn w_from(cols: &[Vec<f64>], lambda: &[usize]) -> Mat {
    let k = lambda.len();
    let mut w = Mat::zeros(k, k);
    for (ti, &i) in lambda.iter().enumerate() {
        for (tj, col) in cols.iter().enumerate() {
            *w.at_mut(ti, tj) = col[i];
        }
    }
    w
}

impl ColumnSampler for Sis {
    fn name(&self) -> &'static str {
        "SIS (naive)"
    }

    fn sample(&self, oracle: &dyn ColumnOracle) -> Result<NystromApprox> {
        self.sample_traced(oracle).map(|(a, _)| a)
    }
}

impl TracedSampler for Sis {
    fn sample_traced(
        &self,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)> {
        Sis::sample_traced(self, oracle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::Gaussian;
    use crate::sampling::oasis::{Oasis, Variant};
    use crate::sampling::ImplicitOracle;

    /// DESIGN.md invariant 3: the accelerated oASIS must reproduce the
    /// naive SIS selection sequence exactly.
    #[test]
    fn oasis_matches_sis_sequence() {
        let ds = two_moons(90, 0.05, 17);
        let kern = Gaussian::new(0.6);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let (_, sis_trace) = Sis::new(18, 3, 1e-12, 5).sample_traced(&oracle).unwrap();
        for variant in [Variant::PaperR, Variant::Incremental] {
            let (_, o_trace) = Oasis::new(18, 3, 1e-12, 5)
                .with_variant(variant)
                .sample_traced(&oracle)
                .unwrap();
            assert_eq!(
                sis_trace.order, o_trace.order,
                "variant {variant:?} diverged from naive SIS"
            );
        }
    }

    #[test]
    fn sis_exact_recovery() {
        let ds = crate::data::generators::gauss_2d_plus_3d(25, 25, 3);
        let g = crate::kernels::kernel_matrix(&ds, &crate::kernels::Linear);
        let oracle = crate::sampling::ExplicitOracle::new(&g);
        let (approx, _) = Sis::new(10, 1, 1e-8, 2).sample_traced(&oracle).unwrap();
        assert!(approx.k() <= 4);
        let err = crate::nystrom::relative_frobenius_error(&oracle, &approx);
        assert!(err < 1e-6, "err {err}");
    }

    /// Warm start (artifact resume), same contract as oASIS's: seeding
    /// from a stored prefix and replaying it reproduces the recording
    /// session's state bit for bit — continued selection, factors, and
    /// the error-estimate state all match an uninterrupted run exactly.
    #[test]
    fn warm_started_sis_is_bit_identical_to_prefix_resume() {
        let ds = two_moons(120, 0.05, 21);
        let kern = Gaussian::with_sigma_fraction(&ds, 0.1);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let sampler = Sis::new(26, 4, 1e-12, 6);
        let (reference, ref_trace) = sampler.sample_traced(&oracle).unwrap();

        let mut prefix = sampler.session(&oracle).unwrap();
        run_to_completion(&mut prefix, &StoppingRule::budget(14)).unwrap();
        let stored: Vec<usize> = prefix.indices().to_vec();

        let mut warm = sampler.session_from_indices(&oracle, &stored).unwrap();
        assert_eq!(warm.k(), 14);
        assert_eq!(warm.indices(), &stored[..]);
        // the replay reproduced the rescoring sweep's residual state
        assert_eq!(
            warm.error_estimate().map(f64::to_bits),
            prefix.error_estimate().map(f64::to_bits),
            "replayed error estimate diverged"
        );
        run_to_completion(&mut warm, &StoppingRule::budget(26)).unwrap();
        let warmed = warm.snapshot().unwrap();
        assert_eq!(warmed.indices, ref_trace.order);
        assert_eq!(warmed.c.data, reference.c.data);
        assert_eq!(warmed.winv.data, reference.winv.data);

        // malformed index sets error cleanly
        assert!(sampler.session_from_indices(&oracle, &[]).is_err());
        assert!(sampler.session_from_indices(&oracle, &[3, 3]).is_err());
        assert!(sampler.session_from_indices(&oracle, &[999]).is_err());
    }

    /// The session path selects the same sequence as the one-shot path
    /// when stepped manually.
    #[test]
    fn sis_session_steps_match_sample() {
        let ds = two_moons(60, 0.05, 8);
        let kern = Gaussian::new(0.7);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let sampler = Sis::new(12, 2, 1e-12, 4);
        let (reference, _) = sampler.sample_traced(&oracle).unwrap();
        let mut s = sampler.session(&oracle).unwrap();
        while s.k() < 12 {
            match s.step().unwrap() {
                StepOutcome::Selected { .. } => {}
                StepOutcome::Exhausted(_) => break,
            }
        }
        let approx = s.snapshot().unwrap();
        assert_eq!(approx.indices, reference.indices);
        assert_eq!(approx.c.data, reference.c.data);
    }
}
