//! File-backed datasets: load points from CSV or the crate's binary
//! matrix format, optionally as per-worker [`Shard`] blocks so oASIS-P
//! nodes each read only their own column block of Z (the paper's
//! Algorithm 2 distributed-data setting).
//!
//! # Formats
//!
//! **CSV** — one point per line, comma-separated numeric fields. Blank
//! lines and `#` comments are skipped; if the *first* data line contains
//! any non-numeric field it is treated as a header row and skipped.
//! Every row must have the same dimensionality and every value must be
//! finite. Numbers parse with Rust's `str::parse::<f64>` — the same
//! routine the JSON request parser uses, so a CSV file and the
//! equivalent inline-points request body yield bit-identical datasets
//! (and therefore identical oASIS selection sequences).
//!
//! **Binary matrix** (`oasis-matrix`) — the same magic-line + JSON
//! header + framed little-endian f64 payload layout as the artifact
//! store (see [`crate::util::framing`]):
//!
//! ```text
//! oasis-matrix\n
//! {"version":1,"n":…,"dim":…,"payload_bytes":…,"checksum":"…"}\n
//! [u64 LE count][count × f64 LE]      ← n×dim point-major values
//! ```
//!
//! Full loads verify the checksum; [`load_shard`] reads only the
//! requested worker's byte range of a binary file (constant memory in n
//! for the other shards) and skips the whole-payload checksum — the
//! per-section frame bound still catches truncation. Note the in-process
//! CLI coordinator (`oasis parallel`) currently loads the whole file and
//! shards in memory; `load_shard` is the building block for deployments
//! where workers open the file themselves (wiring the coordinator's
//! workers to it is a ROADMAP follow-up).
//!
//! # Caps
//!
//! [`LoadLimits`] lets serving callers enforce their existing dataset
//! caps *during* parsing (the row count is checked as it grows, before
//! the file is fully materialized). Library/CLI callers use
//! [`LoadLimits::unlimited`].

use super::{shard_ranges, Dataset, Shard};
use crate::util::framing::{
    checksum_hex, fnv1a64, parse_checksum_hex, push_f64_section,
    split_magic_file, SectionReader,
};
use crate::util::json::Json;
use crate::Result;
use crate::{anyhow, bail};
use std::io::{BufRead, BufReader, Read, Seek, SeekFrom};
use std::path::Path;

/// Binary matrix format version.
pub const MATRIX_FORMAT_VERSION: usize = 1;

/// Magic line opening every binary matrix file (includes the newline).
pub const MATRIX_MAGIC: &[u8] = b"oasis-matrix\n";

/// Size caps applied while a file loads (mirrors the serving layer's
/// `MAX_DATASET_*` limits; see `server::protocol`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LoadLimits {
    pub max_n: usize,
    pub max_dim: usize,
    /// Cap on total n × dim elements.
    pub max_elems: u128,
}

impl LoadLimits {
    /// No caps (library and CLI use).
    pub fn unlimited() -> LoadLimits {
        LoadLimits { max_n: usize::MAX, max_dim: usize::MAX, max_elems: u128::MAX }
    }

    pub(crate) fn check_dim(&self, dim: usize) -> Result<()> {
        if dim == 0 {
            bail!("dataset rows must have dimension ≥ 1");
        }
        if dim > self.max_dim {
            bail!("dataset dimension {dim} exceeds the cap of {}", self.max_dim);
        }
        Ok(())
    }

    pub(crate) fn check_n(&self, n: usize, dim: usize) -> Result<()> {
        if n > self.max_n {
            bail!("dataset has more than {} rows", self.max_n);
        }
        if (n as u128) * (dim as u128) > self.max_elems {
            bail!(
                "dataset n×dim exceeds the cap of {} elements",
                self.max_elems
            );
        }
        Ok(())
    }
}

/// Does `f` open with the binary [`MATRIX_MAGIC`] line? Rewinds to the
/// start either way, so format sniffing stays identical across
/// [`load_dataset`], [`load_shard`], and [`peek_matrix_dims`].
fn sniff_binary(f: &mut std::fs::File, path: &Path) -> Result<bool> {
    let mut probe = vec![0u8; MATRIX_MAGIC.len()];
    let is_binary = match f.read_exact(&mut probe) {
        Ok(()) => probe == MATRIX_MAGIC,
        Err(_) => false, // shorter than the magic: can only be CSV
    };
    f.seek(SeekFrom::Start(0))
        .map_err(|e| anyhow!("seeking {}: {e}", path.display()))?;
    Ok(is_binary)
}

/// Load a dataset from `path`, sniffing the format: files opening with
/// the [`MATRIX_MAGIC`] line are binary, anything else parses as CSV.
pub fn load_dataset(path: &Path, limits: &LoadLimits) -> Result<Dataset> {
    let mut f = open(path)?;
    let res = if sniff_binary(&mut f, path)? {
        load_matrix_file(&mut f, limits)
    } else {
        load_csv_reader(BufReader::new(f), limits)
    };
    res.map_err(|e| e.wrap(format!("loading dataset {}", path.display())))
}

/// Load only worker `worker`'s shard (of `p`) from `path` — the
/// contiguous row block [`shard_ranges`] assigns it. Binary files are
/// read by byte range (O(shard) memory — the format for large
/// distributed deployments); CSV files have no row index, so the whole
/// file is parsed and then sliced (O(n) peak memory per worker).
pub fn load_shard(
    path: &Path,
    worker: usize,
    p: usize,
    limits: &LoadLimits,
) -> Result<Shard> {
    if worker >= p {
        bail!("worker {worker} out of range for {p} shards");
    }
    let mut f = open(path)?;
    let res = if sniff_binary(&mut f, path)? {
        load_matrix_shard(&mut f, worker, p, limits)
    } else {
        let ds = load_csv_reader(BufReader::new(f), limits)?;
        let range = shard_range(ds.n(), worker, p);
        Ok(Shard {
            worker,
            start: range.start,
            points: ds.slice(range.start, range.end),
        })
    };
    res.map_err(|e| {
        e.wrap(format!("loading shard {worker}/{p} of {}", path.display()))
    })
}

/// Load an arbitrary row range `[start, start + len)` from `path` —
/// the building block the distributed coordinator uses when a surviving
/// worker adopts a dead peer's rows: the adopted block is re-read
/// straight from the dataset file, not shipped over the wire. Binary
/// files are read by byte range (O(len) memory); CSV files are parsed
/// whole and sliced, like [`load_shard`].
pub fn load_rows(
    path: &Path,
    start: usize,
    len: usize,
    limits: &LoadLimits,
) -> Result<Dataset> {
    let mut f = open(path)?;
    let res = if sniff_binary(&mut f, path)? {
        load_matrix_rows(&mut f, start, len, limits)
    } else {
        let ds = load_csv_reader(BufReader::new(f), limits)?;
        if start + len > ds.n() {
            bail!("rows {start}..{} out of range for n = {}", start + len, ds.n());
        }
        Ok(ds.slice(start, start + len))
    };
    res.map_err(|e| {
        e.wrap(format!(
            "loading rows {start}..{} of {}",
            start + len,
            path.display()
        ))
    })
}

/// Read only a binary matrix file's header, returning `(n, dim)` without
/// touching the payload — how a shard-read oASIS-P leader learns the
/// dataset size while its workers read their own byte ranges. Errors
/// (with a pointer at the fix) for CSV files, which have no header to
/// peek.
pub fn peek_matrix_dims(path: &Path) -> Result<(usize, usize)> {
    let mut f = open(path)?;
    if !sniff_binary(&mut f, path)? {
        bail!(
            "{} is not an oasis-matrix binary file — per-worker shard reads \
             need the binary format (write one with data::loader::save_matrix)",
            path.display()
        );
    }
    let (n, dim, _payload, _checksum, _offset) = read_matrix_header(&mut f)
        .map_err(|e| e.wrap(format!("reading header of {}", path.display())))?;
    Ok((n, dim))
}

/// Write `ds` to `path` in the binary matrix format.
pub fn save_matrix(path: &Path, ds: &Dataset) -> Result<usize> {
    let mut payload = Vec::new();
    push_f64_section(&mut payload, ds.flat());
    let header = Json::obj(vec![
        ("version", Json::Num(MATRIX_FORMAT_VERSION as f64)),
        ("n", Json::Num(ds.n() as f64)),
        ("dim", Json::Num(ds.dim() as f64)),
        ("payload_bytes", Json::Num(payload.len() as f64)),
        ("checksum", Json::Str(checksum_hex(fnv1a64(&payload)))),
    ]);
    let mut out = Vec::with_capacity(MATRIX_MAGIC.len() + payload.len() + 128);
    out.extend_from_slice(MATRIX_MAGIC);
    out.extend_from_slice(header.to_string().as_bytes());
    out.push(b'\n');
    out.extend_from_slice(&payload);
    crate::util::fsio::write_atomic(path, &out)
        .map_err(|e| e.wrap(format!("writing matrix {}", path.display())))?;
    Ok(out.len())
}

/// Write `ds` to `path` as CSV. Values use Rust's shortest-round-trip
/// f64 formatting, so `save_csv` → CSV load is bit-exact.
pub fn save_csv(path: &Path, ds: &Dataset) -> Result<()> {
    let mut out = String::new();
    for i in 0..ds.n() {
        for (j, v) in ds.point(i).iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            out.push_str(&format!("{v}"));
        }
        out.push('\n');
    }
    crate::util::fsio::write_atomic(path, out.as_bytes())
        .map_err(|e| e.wrap(format!("writing csv {}", path.display())))
}

fn open(path: &Path) -> Result<std::fs::File> {
    std::fs::File::open(path)
        .map_err(|e| anyhow!("opening {}: {e}", path.display()))
}

/// Cap on one CSV line: `lines()`-style reading would buffer a
/// newline-free multi-GB file whole before any per-row limit applied.
const MAX_CSV_LINE_BYTES: u64 = 16 * 1024 * 1024;

/// Parse CSV text from any reader (exposed for tests and in-memory use
/// via `load_csv_str`). Reads line-by-line with a per-line byte cap, so
/// [`LoadLimits`] genuinely bound memory *during* the parse.
fn load_csv_reader<R: BufRead>(
    mut reader: R,
    limits: &LoadLimits,
) -> Result<Dataset> {
    let mut dim: Option<usize> = None;
    let mut data: Vec<f64> = Vec::new();
    let mut n = 0usize;
    let mut first_data_line = true;
    let mut lineno = 0usize;
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        let got = std::io::Read::by_ref(&mut reader)
            .take(MAX_CSV_LINE_BYTES)
            .read_until(b'\n', &mut buf)
            .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        if got == 0 {
            break;
        }
        lineno += 1;
        if buf.last() != Some(&b'\n') && got as u64 == MAX_CSV_LINE_BYTES {
            bail!("line {lineno}: longer than {MAX_CSV_LINE_BYTES} bytes");
        }
        let line = std::str::from_utf8(&buf)
            .map_err(|_| anyhow!("line {lineno}: not UTF-8"))?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        match parse_csv_row(trimmed) {
            Ok(row) => {
                match dim {
                    None => {
                        limits.check_dim(row.len())?;
                        dim = Some(row.len());
                    }
                    Some(d) if d != row.len() => bail!(
                        "line {lineno}: row has {} fields but previous \
                         rows have {d}",
                        row.len()
                    ),
                    _ => {}
                }
                n += 1;
                limits.check_n(n, dim.unwrap())?;
                data.extend_from_slice(&row);
                first_data_line = false;
            }
            Err(e) => {
                // Only a *fully* non-numeric first line is a header row
                // ("x,y", "id,value"). A first data row with one bad
                // field ("0.5,inf", "1.0,2x") must error like any other
                // row — silently skipping it would shift every row index.
                let is_header = first_data_line
                    && trimmed
                        .split(',')
                        .all(|f| f.trim().parse::<f64>().is_err());
                if is_header {
                    first_data_line = false;
                    continue;
                }
                return Err(e.wrap(format!("line {lineno}")));
            }
        }
    }
    match dim {
        Some(d) if n > 0 => Ok(Dataset::from_flat(d, data)),
        _ => bail!("no data rows found"),
    }
}

/// Parse one CSV data row into finite f64 fields.
fn parse_csv_row(line: &str) -> Result<Vec<f64>> {
    let mut out = Vec::new();
    for field in line.split(',') {
        let field = field.trim();
        if field.is_empty() {
            bail!("empty field");
        }
        let x: f64 = field
            .parse()
            .map_err(|_| anyhow!("field {field:?} is not a number"))?;
        if !x.is_finite() {
            bail!("field {field:?} is not finite");
        }
        out.push(x);
    }
    Ok(out)
}

/// Read the binary header (magic + JSON line) off `f`, returning
/// `(n, dim, payload_bytes, checksum, payload_offset)`.
fn read_matrix_header(
    f: &mut std::fs::File,
) -> Result<(usize, usize, usize, u64, u64)> {
    // headers are small; read a bounded prefix to find the two newlines
    let mut prefix = vec![0u8; 4096];
    let got = read_up_to(f, &mut prefix)?;
    let prefix = &prefix[..got];
    let (header_str, _) = split_magic_file(prefix, MATRIX_MAGIC, "oasis matrix")?;
    let header_end = MATRIX_MAGIC.len() + header_str.len() + 1;
    let h = Json::parse(header_str).map_err(|e| anyhow!("matrix header: {e}"))?;
    let version = h
        .get("version")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("matrix header missing version"))?;
    if version != MATRIX_FORMAT_VERSION {
        bail!(
            "unsupported matrix version {version} (this build reads version \
             {MATRIX_FORMAT_VERSION})"
        );
    }
    let field = |key: &str| -> Result<usize> {
        h.get(key)
            .and_then(Json::as_f64)
            .filter(|x| x.is_finite() && *x >= 0.0 && x.fract() == 0.0)
            .map(|x| x as usize)
            .ok_or_else(|| anyhow!("matrix header field '{key}' missing"))
    };
    let n = field("n")?;
    let dim = field("dim")?;
    let payload_bytes = field("payload_bytes")?;
    let checksum = parse_checksum_hex(
        h.get("checksum")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("matrix header missing checksum"))?,
    )?;
    if n == 0 || dim == 0 {
        bail!("matrix header has empty dimensions (n={n}, dim={dim})");
    }
    Ok((n, dim, payload_bytes, checksum, header_end as u64))
}

/// `Read::read` until the buffer is full or EOF; returns bytes read.
fn read_up_to(f: &mut std::fs::File, buf: &mut [u8]) -> Result<usize> {
    let mut got = 0;
    while got < buf.len() {
        let k = f.read(&mut buf[got..]).map_err(|e| anyhow!("read: {e}"))?;
        if k == 0 {
            break;
        }
        got += k;
    }
    Ok(got)
}

/// `n × dim` with overflow-checked arithmetic: a crafted header must be
/// a clean error, not a panic or a wrapped-to-zero allocation.
fn checked_matrix_elems(n: usize, dim: usize) -> Result<usize> {
    let elems = (n as u128) * (dim as u128);
    if elems > (1u128 << 48) {
        bail!("matrix header implies an implausible size ({n}×{dim})");
    }
    Ok(elems as usize)
}

fn load_matrix_file(f: &mut std::fs::File, limits: &LoadLimits) -> Result<Dataset> {
    let (n, dim, payload_bytes, checksum, offset) = read_matrix_header(f)?;
    limits.check_dim(dim)?;
    limits.check_n(n, dim)?;
    let elems = checked_matrix_elems(n, dim)?;
    // the payload must be exactly the one framed section n×dim implies —
    // checked *before* reading, so a small header cannot front an
    // arbitrarily large read
    if payload_bytes != 8 + elems * 8 {
        bail!(
            "matrix payload_bytes {payload_bytes} inconsistent with \
             n×dim = {n}×{dim}"
        );
    }
    f.seek(SeekFrom::Start(offset)).map_err(|e| anyhow!("seek: {e}"))?;
    let mut payload = Vec::new();
    // +1 so trailing garbage is detected without materializing it
    f.take(payload_bytes as u64 + 1)
        .read_to_end(&mut payload)
        .map_err(|e| anyhow!("read: {e}"))?;
    if payload.len() != payload_bytes {
        bail!(
            "matrix payload is {} bytes but the header promises \
             {payload_bytes} (truncated or trailing garbage)",
            if payload.len() > payload_bytes {
                format!("over {payload_bytes}")
            } else {
                payload.len().to_string()
            }
        );
    }
    let got = fnv1a64(&payload);
    if got != checksum {
        bail!(
            "matrix checksum mismatch: payload hashes to {} but the header \
             says {} (corrupted file)",
            checksum_hex(got),
            checksum_hex(checksum)
        );
    }
    let mut r = SectionReader::new(&payload);
    let data = r.read_f64_section(elems, "matrix values")?;
    if r.remaining() != 0 {
        bail!("matrix payload has {} unread trailing bytes", r.remaining());
    }
    for (i, &v) in data.iter().enumerate() {
        if !v.is_finite() {
            bail!("matrix value {i} is not finite");
        }
    }
    Ok(Dataset::from_flat(dim, data))
}

/// Read only one worker's row block of a binary matrix: seek past the
/// frame's length prefix to `start×dim` values and read `len×dim`.
fn load_matrix_shard(
    f: &mut std::fs::File,
    worker: usize,
    p: usize,
    limits: &LoadLimits,
) -> Result<Shard> {
    let (n, _, _, _, _) = read_matrix_header_checked(f, limits)?;
    f.seek(SeekFrom::Start(0)).map_err(|e| anyhow!("seek: {e}"))?;
    let range = shard_range(n, worker, p);
    let points =
        load_matrix_rows(f, range.start, range.end - range.start, limits)?;
    Ok(Shard { worker, start: range.start, points })
}

/// Header read + the size/consistency checks shared by every byte-range
/// reader, returning `(n, dim, elems, payload_bytes, offset)`.
fn read_matrix_header_checked(
    f: &mut std::fs::File,
    limits: &LoadLimits,
) -> Result<(usize, usize, usize, usize, u64)> {
    let (n, dim, payload_bytes, _checksum, offset) = read_matrix_header(f)?;
    limits.check_dim(dim)?;
    limits.check_n(n, dim)?;
    let elems = checked_matrix_elems(n, dim)?;
    if payload_bytes != 8 + elems * 8 {
        bail!(
            "matrix payload_bytes {payload_bytes} inconsistent with \
             n×dim = {n}×{dim}"
        );
    }
    Ok((n, dim, elems, payload_bytes, offset))
}

/// Read rows `[start, start + len)` of a binary matrix by byte range.
fn load_matrix_rows(
    f: &mut std::fs::File,
    start: usize,
    len: usize,
    limits: &LoadLimits,
) -> Result<Dataset> {
    let (n, dim, elems, _payload_bytes, offset) =
        read_matrix_header_checked(f, limits)?;
    if start + len > n {
        bail!("rows {start}..{} out of range for n = {n}", start + len);
    }
    let count = len * dim;
    // offset → [u64 frame count][values…]; verify the frame count first
    f.seek(SeekFrom::Start(offset)).map_err(|e| anyhow!("seek: {e}"))?;
    let mut lenbuf = [0u8; 8];
    f.read_exact(&mut lenbuf)
        .map_err(|e| anyhow!("reading frame header: {e}"))?;
    let framed = u64::from_le_bytes(lenbuf);
    if framed != elems as u64 {
        bail!("matrix frame holds {framed} values but the header implies {elems}");
    }
    f.seek(SeekFrom::Current((start * dim * 8) as i64))
        .map_err(|e| anyhow!("seek: {e}"))?;
    let mut raw = vec![0u8; count * 8];
    f.read_exact(&mut raw)
        .map_err(|e| anyhow!("reading shard rows: {e} (truncated file?)"))?;
    let mut data = Vec::with_capacity(count);
    for chunk in raw.chunks_exact(8) {
        let v = f64::from_le_bytes(chunk.try_into().unwrap());
        if !v.is_finite() {
            bail!("shard value is not finite");
        }
        data.push(v);
    }
    Ok(Dataset::from_flat(dim, data))
}

/// This worker's row range. [`shard_ranges`] yields `min(p, n)` ranges
/// (never an empty one), so workers past that own an empty block at the
/// end — mirroring how `shard::split` would leave them without a shard.
fn shard_range(n: usize, worker: usize, p: usize) -> std::ops::Range<usize> {
    shard_ranges(n, p).get(worker).cloned().unwrap_or(n..n)
}

/// Parse CSV from an in-memory string (tests, inline comparisons).
pub fn load_csv_str(text: &str, limits: &LoadLimits) -> Result<Dataset> {
    load_csv_reader(BufReader::new(text.as_bytes()), limits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::data::shard::split;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("oasis-loader-test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn csv_parses_with_comments_header_and_blank_lines() {
        let text = "# a comment\nx,y\n\n1.5,2.5\n-3,4e-2\n# mid comment\n0.1,0.2\n";
        let ds = load_csv_str(text, &LoadLimits::unlimited()).unwrap();
        assert_eq!((ds.n(), ds.dim()), (3, 2));
        assert_eq!(ds.point(0), &[1.5, 2.5]);
        assert_eq!(ds.point(1), &[-3.0, 0.04]);
    }

    #[test]
    fn csv_rejects_bad_rows() {
        let lim = LoadLimits::unlimited();
        // ragged
        assert!(load_csv_str("1,2\n3\n", &lim).is_err());
        // non-numeric after the first data row
        assert!(load_csv_str("1,2\nx,y\n", &lim).is_err());
        // non-finite
        assert!(load_csv_str("1,inf\n", &lim).is_err());
        // empty field
        assert!(load_csv_str("1,,2\n", &lim).is_err());
        // nothing at all
        assert!(load_csv_str("# only comments\n", &lim).is_err());
    }

    /// A malformed *first* data row must error, not be silently skipped
    /// as a header — skipping would shift every row index by one.
    #[test]
    fn csv_header_sniffing_is_strict() {
        let lim = LoadLimits::unlimited();
        // partially-numeric first lines are data with an error
        assert!(load_csv_str("0.5,inf\n1,2\n", &lim).is_err());
        assert!(load_csv_str("1.0,2x\n1,2\n", &lim).is_err());
        assert!(load_csv_str("x,1\n1,2\n", &lim).is_err());
        // fully non-numeric first line is still a header
        let ds = load_csv_str("id,value\n1,2\n3,4\n", &lim).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.point(0), &[1.0, 2.0]);
    }

    #[test]
    fn csv_limits_enforced_during_parse() {
        let lim = LoadLimits { max_n: 2, max_dim: 8, max_elems: u128::MAX };
        assert!(load_csv_str("1\n2\n", &lim).is_ok());
        assert!(load_csv_str("1\n2\n3\n", &lim).is_err());
        let lim = LoadLimits { max_n: 100, max_dim: 1, max_elems: u128::MAX };
        assert!(load_csv_str("1,2\n", &lim).is_err());
    }

    #[test]
    fn binary_matrix_round_trips_bit_exactly() {
        let ds = two_moons(37, 0.05, 9);
        let path = tmp("roundtrip.mat");
        save_matrix(&path, &ds).unwrap();
        let back = load_dataset(&path, &LoadLimits::unlimited()).unwrap();
        assert_eq!(back.n(), ds.n());
        assert_eq!(back.dim(), ds.dim());
        for (a, b) in ds.flat().iter().zip(back.flat()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn csv_save_load_round_trips_bit_exactly() {
        let ds = two_moons(23, 0.05, 4);
        let path = tmp("roundtrip.csv");
        save_csv(&path, &ds).unwrap();
        let back = load_dataset(&path, &LoadLimits::unlimited()).unwrap();
        assert_eq!(back.dim(), ds.dim());
        for (a, b) in ds.flat().iter().zip(back.flat()) {
            assert_eq!(a.to_bits(), b.to_bits(), "shortest-round-trip failed");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn binary_corruption_and_truncation_rejected() {
        let ds = two_moons(10, 0.05, 1);
        let path = tmp("corrupt.mat");
        save_matrix(&path, &ds).unwrap();
        let bytes = std::fs::read(&path).unwrap();

        // truncated
        let cut_path = tmp("cut.mat");
        std::fs::write(&cut_path, &bytes[..bytes.len() - 5]).unwrap();
        let err = load_dataset(&cut_path, &LoadLimits::unlimited()).unwrap_err();
        assert!(format!("{err}").contains("truncated"), "{err}");

        // flipped payload byte
        let mut flipped = bytes.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x01;
        let flip_path = tmp("flip.mat");
        std::fs::write(&flip_path, &flipped).unwrap();
        let err = load_dataset(&flip_path, &LoadLimits::unlimited()).unwrap_err();
        assert!(format!("{err}").contains("checksum"), "{err}");

        // wrong version
        let text = String::from_utf8_lossy(&bytes).into_owned();
        let bumped = text.replacen("\"version\":1", "\"version\":9", 1);
        let v_path = tmp("badver.mat");
        std::fs::write(&v_path, bumped.as_bytes()).unwrap();
        let err = load_dataset(&v_path, &LoadLimits::unlimited()).unwrap_err();
        assert!(format!("{err}").contains("version 9"), "{err}");

        for p in [&path, &cut_path, &flip_path, &v_path] {
            std::fs::remove_file(p).ok();
        }
    }

    /// `load_shard` must reproduce exactly what in-memory sharding of the
    /// full dataset produces, for both formats.
    #[test]
    fn shard_loads_match_in_memory_split() {
        let ds = two_moons(53, 0.05, 6);
        let lim = LoadLimits::unlimited();
        let bin = tmp("shards.mat");
        let csv = tmp("shards.csv");
        save_matrix(&bin, &ds).unwrap();
        save_csv(&csv, &ds).unwrap();
        let p = 4;
        let want = split(&ds, p);
        for path in [&bin, &csv] {
            for w in 0..p {
                let shard = load_shard(path, w, p, &lim).unwrap();
                assert_eq!(shard.worker, want[w].worker);
                assert_eq!(shard.start, want[w].start);
                assert_eq!(shard.points.n(), want[w].points.n());
                for (a, b) in
                    shard.points.flat().iter().zip(want[w].points.flat())
                {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
        }
        assert!(load_shard(&bin, p, p, &lim).is_err(), "worker out of range");
        std::fs::remove_file(&bin).ok();
        std::fs::remove_file(&csv).ok();
    }

    /// `load_rows` reads arbitrary ranges bit-identically to in-memory
    /// slicing, for both formats, and refuses out-of-range requests.
    #[test]
    fn arbitrary_row_ranges_match_in_memory_slices() {
        let ds = two_moons(41, 0.05, 8);
        let lim = LoadLimits::unlimited();
        let bin = tmp("rows.mat");
        let csv = tmp("rows.csv");
        save_matrix(&bin, &ds).unwrap();
        save_csv(&csv, &ds).unwrap();
        for path in [&bin, &csv] {
            for (start, len) in [(0usize, 41usize), (7, 12), (40, 1), (13, 0)] {
                let rows = load_rows(path, start, len, &lim).unwrap();
                assert_eq!(rows.n(), len);
                let want = ds.slice(start, start + len);
                for (a, b) in rows.flat().iter().zip(want.flat()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            assert!(load_rows(path, 30, 12, &lim).is_err(), "past the end");
        }
        std::fs::remove_file(&bin).ok();
        std::fs::remove_file(&csv).ok();
    }
}
