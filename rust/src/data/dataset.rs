//! The point container: n points of dimension m, stored point-major so a
//! point is one contiguous slice (cache-friendly for kernel evaluation).
//! The paper arranges data columnwise as Z ∈ R^{m×n}; `Dataset` is Zᵀ.

/// A dataset of `n` points in `R^dim`.
#[derive(Clone, Debug, PartialEq)]
pub struct Dataset {
    dim: usize,
    data: Vec<f64>,
}

impl Dataset {
    /// Create from a flat point-major buffer (`data.len() == n*dim`).
    pub fn from_flat(dim: usize, data: Vec<f64>) -> Dataset {
        assert!(dim > 0 && data.len() % dim == 0);
        Dataset { dim, data }
    }

    /// Create from per-point rows.
    pub fn from_rows(rows: Vec<Vec<f64>>) -> Dataset {
        assert!(!rows.is_empty());
        let dim = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * dim);
        for r in rows {
            assert_eq!(r.len(), dim, "ragged rows");
            data.extend_from_slice(&r);
        }
        Dataset { dim, data }
    }

    /// Pre-sized zero dataset (filled by generators).
    pub fn zeros(n: usize, dim: usize) -> Dataset {
        Dataset { dim, data: vec![0.0; n * dim] }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.data.len() / self.dim
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    pub fn point(&self, i: usize) -> &[f64] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    #[inline]
    pub fn point_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.dim..(i + 1) * self.dim]
    }

    pub fn flat(&self) -> &[f64] {
        &self.data
    }

    /// A new dataset containing the selected points (e.g. Z_Λ).
    pub fn select(&self, idx: &[usize]) -> Dataset {
        let mut out = Dataset::zeros(idx.len(), self.dim);
        for (r, &i) in idx.iter().enumerate() {
            out.point_mut(r).copy_from_slice(self.point(i));
        }
        out
    }

    /// Contiguous sub-range of points [start, end) as an owned dataset.
    pub fn slice(&self, start: usize, end: usize) -> Dataset {
        assert!(start <= end && end <= self.n());
        Dataset {
            dim: self.dim,
            data: self.data[start * self.dim..end * self.dim].to_vec(),
        }
    }

    /// Append one point.
    pub fn push(&mut self, p: &[f64]) {
        assert_eq!(p.len(), self.dim);
        self.data.extend_from_slice(p);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing() {
        let ds = Dataset::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.dim(), 2);
        assert_eq!(ds.point(1), &[3.0, 4.0]);
    }

    #[test]
    fn select_and_slice() {
        let ds = Dataset::from_rows(vec![
            vec![0.0],
            vec![1.0],
            vec![2.0],
            vec![3.0],
        ]);
        assert_eq!(ds.select(&[3, 0]).point(0), &[3.0]);
        let s = ds.slice(1, 3);
        assert_eq!(s.n(), 2);
        assert_eq!(s.point(0), &[1.0]);
    }

    #[test]
    fn push_grows() {
        let mut ds = Dataset::zeros(0, 3);
        ds.push(&[1.0, 2.0, 3.0]);
        assert_eq!(ds.n(), 1);
        assert_eq!(ds.point(0), &[1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic]
    fn ragged_rows_rejected() {
        Dataset::from_rows(vec![vec![1.0], vec![1.0, 2.0]]);
    }
}
