//! Datasets: the point container, the synthetic generators matching the
//! paper's evaluation workloads, and sharding for oASIS-P.

pub mod dataset;
pub mod generators;
pub mod shard;

pub use dataset::Dataset;
pub use shard::{shard_ranges, Shard};
