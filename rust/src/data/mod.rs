//! Datasets: the point container, the synthetic generators matching the
//! paper's evaluation workloads, sharding for oASIS-P, and file-backed
//! loading (CSV / binary matrix, whole or per-worker shard) in
//! [`loader`].

pub mod dataset;
pub mod generators;
pub mod loader;
pub mod shard;

pub use dataset::Dataset;
pub use loader::{load_dataset, load_shard, save_csv, save_matrix, LoadLimits};
pub use shard::{shard_ranges, Shard};
