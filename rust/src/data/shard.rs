//! Dataset sharding for oASIS-P: contiguous column blocks of Z per node,
//! exactly as the paper's Algorithm 2 loads "separate n/p column blocks of
//! Z into each node".

use super::Dataset;

/// One worker's shard: the points it owns and their global index range.
#[derive(Clone, Debug)]
pub struct Shard {
    pub worker: usize,
    /// global index of the first point in this shard
    pub start: usize,
    pub points: Dataset,
}

impl Shard {
    #[inline]
    pub fn len(&self) -> usize {
        self.points.n()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Does this shard own global index `g`?
    #[inline]
    pub fn owns(&self, g: usize) -> bool {
        g >= self.start && g < self.start + self.len()
    }

    /// Global → local index.
    #[inline]
    pub fn local(&self, g: usize) -> usize {
        debug_assert!(self.owns(g));
        g - self.start
    }
}

/// The contiguous [start, end) global ranges for `p` shards of `n` points.
pub fn shard_ranges(n: usize, p: usize) -> Vec<std::ops::Range<usize>> {
    crate::util::parallel::chunk_ranges(n, p)
}

/// Split a dataset into `p` shards (cloning the point data — each "node"
/// owns its block, as in the distributed setting being simulated).
pub fn split(ds: &Dataset, p: usize) -> Vec<Shard> {
    shard_ranges(ds.n(), p)
        .into_iter()
        .enumerate()
        .map(|(worker, r)| Shard {
            worker,
            start: r.start,
            points: ds.slice(r.start, r.end),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;

    #[test]
    fn shards_partition_exactly() {
        let ds = two_moons(103, 0.05, 1);
        let shards = split(&ds, 4);
        assert_eq!(shards.len(), 4);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 103);
        // ownership is a partition
        for g in 0..103 {
            let owners = shards.iter().filter(|s| s.owns(g)).count();
            assert_eq!(owners, 1, "index {g}");
        }
    }

    #[test]
    fn shard_points_match_source() {
        let ds = two_moons(50, 0.05, 2);
        for s in split(&ds, 3) {
            for l in 0..s.len() {
                assert_eq!(s.points.point(l), ds.point(s.start + l));
            }
        }
    }

    #[test]
    fn local_index_roundtrip() {
        let ds = two_moons(20, 0.05, 3);
        let shards = split(&ds, 6);
        for s in &shards {
            for g in s.start..s.start + s.len() {
                assert_eq!(s.start + s.local(g), g);
            }
        }
    }

    #[test]
    fn more_shards_than_points() {
        let ds = two_moons(3, 0.05, 4);
        let shards = split(&ds, 8);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 3);
        assert!(shards.iter().all(|s| !s.is_empty()));
    }
}
