//! Synthetic dataset generators matching the paper's evaluation workloads.
//!
//! Where the paper used downloadable datasets that are unavailable offline
//! (Abalone, MNIST, Salinas, Light Field, Tiny Images) the generators below
//! reproduce the *structural* properties Nyström approximation is sensitive
//! to — size, dimensionality, cluster count, and intrinsic rank / spectral
//! decay — per the substitution table in DESIGN.md §6.

use super::Dataset;
use crate::util::rng::Pcg64;

/// Dispatch a generator by its CLI/server name. `dim` of 0 means the
/// generator's default dimensionality; `noise` applies to two-moons
/// only. Returns `None` for an unknown name — callers own the error
/// reporting. Shared by `oasis approximate` (`main.rs`, which XORs its
/// `--seed` with `0xDA7A` first so dataset and sampler RNG streams
/// differ) and the serving layer (`server::protocol`, which passes seeds
/// raw), so the name table cannot drift between the two.
pub fn by_name(
    name: &str,
    n: usize,
    dim: usize,
    noise: f64,
    seed: u64,
) -> Option<Dataset> {
    Some(match name {
        "two-moons" => two_moons(n, noise, seed),
        "abalone" => abalone_like(n, seed),
        "borg" => borg(8, (n / 256).max(1), 0.1, seed),
        "mnist" => mnist_like(n, if dim > 0 { dim } else { 784 }, seed),
        "salinas" => salinas_like(n, if dim > 0 { dim } else { 204 }, seed),
        "lightfield" => lightfield_like(n, seed),
        "tiny-images" => tiny_images_like(n, 32, seed),
        _ => return None,
    })
}

/// The dimensionality [`by_name`] will produce for these arguments —
/// lets the serving layer validate n×dim *before* any allocation.
pub fn dim_by_name(name: &str, dim: usize) -> Option<usize> {
    Some(match name {
        "two-moons" => 2,
        "abalone" => 8,
        "borg" => 8,
        "mnist" => {
            if dim > 0 {
                dim
            } else {
                784
            }
        }
        "salinas" => {
            if dim > 0 {
                dim
            } else {
                204
            }
        }
        "lightfield" => 400,
        "tiny-images" => 32 * 32,
        _ => return None,
    })
}

/// Two interlocking moons in 2-D (paper §V-B-a and §V-D-g).
///
/// `noise` is the Gaussian jitter std as a fraction of the unit radius.
pub fn two_moons(n: usize, noise: f64, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed);
    let mut ds = Dataset::zeros(n, 2);
    for i in 0..n {
        let upper = i % 2 == 0;
        let t = std::f64::consts::PI * rng.f64();
        let (x, y) = if upper {
            (t.cos(), t.sin())
        } else {
            (1.0 - t.cos(), 0.5 - t.sin())
        };
        let p = ds.point_mut(i);
        p[0] = x + noise * rng.normal();
        p[1] = y + noise * rng.normal();
    }
    ds
}

/// BORG: Binary Organization of Random Gaussians (paper §V-B-c).
///
/// Points clustered tightly around each vertex of a `dim`-dimensional unit
/// cube: around each vertex v, `per_vertex` points ~ N(v, σ²I). The paper
/// uses dim=8, per_vertex=30, σ²=0.1 → 7,680 points.
pub fn borg(dim: usize, per_vertex: usize, sigma_sq: f64, seed: u64) -> Dataset {
    assert!(dim <= 20, "borg: 2^dim vertices explode past dim 20");
    let mut rng = Pcg64::new(seed);
    let vertices = 1usize << dim;
    let n = vertices * per_vertex;
    let sigma = sigma_sq.sqrt();
    let mut ds = Dataset::zeros(n, dim);
    let mut i = 0;
    for v in 0..vertices {
        for _ in 0..per_vertex {
            let p = ds.point_mut(i);
            for (d, x) in p.iter_mut().enumerate() {
                let vert = ((v >> d) & 1) as f64;
                *x = vert + sigma * rng.normal();
            }
            i += 1;
        }
    }
    ds
}

/// The Fig. 5 synthetic: a 2-D Gaussian centered at (0,0) plus a 3-D
/// Gaussian centered at (0,0,1), embedded together in R³. The resulting
/// Gram matrix G = ZᵀZ has rank exactly 3 (generically), which oASIS must
/// recover in 3 steps (Theorem 1).
pub fn gauss_2d_plus_3d(n_2d: usize, n_3d: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed);
    let mut ds = Dataset::zeros(n_2d + n_3d, 3);
    for i in 0..n_2d {
        let p = ds.point_mut(i);
        p[0] = rng.normal();
        p[1] = rng.normal();
        p[2] = 0.0;
    }
    for i in 0..n_3d {
        let p = ds.point_mut(n_2d + i);
        p[0] = rng.normal();
        p[1] = rng.normal();
        p[2] = 1.0 + rng.normal();
    }
    ds
}

/// Abalone-like (paper §V-B-b: 4,177 points, 8 physical measurements).
///
/// Three overlapping "sex" classes (infant/female/male) whose 8 features
/// are strongly correlated with a latent size variable — matching the real
/// dataset's structure of correlated morphometrics with mild clustering.
pub fn abalone_like(n: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed);
    let mut ds = Dataset::zeros(n, 8);
    // per-class latent size distribution (infants smaller)
    let class_mu = [0.35, 0.55, 0.60];
    let class_sd = [0.08, 0.10, 0.10];
    // feature = a * size + b + noise  (a, b per feature, roughly matching
    // length/diameter/height/4 weights/rings of the UCI set)
    let coef = [
        (1.00, 0.00, 0.02),
        (0.80, 0.01, 0.02),
        (0.28, 0.00, 0.01),
        (2.20, -0.30, 0.10),
        (0.95, -0.12, 0.05),
        (0.49, -0.07, 0.03),
        (0.65, -0.09, 0.04),
        (14.0, 2.00, 2.00),
    ];
    for i in 0..n {
        let c = rng.below(3);
        let size = (class_mu[c] + class_sd[c] * rng.normal()).max(0.05);
        let p = ds.point_mut(i);
        for (f, &(a, b, s)) in coef.iter().enumerate() {
            p[f] = (a * size + b + s * rng.normal()).max(0.0);
        }
    }
    ds
}

/// A mixture of isotropic Gaussian clouds (general-purpose cluster data).
pub fn gaussian_clusters(
    n: usize,
    dim: usize,
    k: usize,
    spread: f64,
    seed: u64,
) -> Dataset {
    let mut rng = Pcg64::new(seed);
    // cluster centers uniform in [0, 10]^dim
    let centers: Vec<Vec<f64>> = (0..k)
        .map(|_| (0..dim).map(|_| rng.range(0.0, 10.0)).collect())
        .collect();
    let mut ds = Dataset::zeros(n, dim);
    for i in 0..n {
        let c = &centers[i % k];
        let p = ds.point_mut(i);
        for (d, x) in p.iter_mut().enumerate() {
            *x = c[d] + spread * rng.normal();
        }
    }
    ds
}

/// MNIST-like (paper §V-C-d: 50,000 points, 784 dims, intrinsic rank ~10).
///
/// Ten smooth random "digit prototypes" in `dim` dimensions; each point is
/// a prototype plus small within-class deformation along a low-dimensional
/// class subspace plus pixel noise — giving the strong 10-cluster low-rank
/// structure that makes MNIST similarity matrices low-rank.
pub fn mnist_like(n: usize, dim: usize, seed: u64) -> Dataset {
    low_rank_classes(n, dim, 10, 6, 0.35, 0.04, seed)
}

/// Salinas-like hyperspectral (paper §V-C-e: 54,129 pixels, 204 bands,
/// 16 crop classes). Spectra are smooth over the band axis: each class
/// endmember is a random smooth curve, each pixel a noisy scaled endmember
/// (linear mixing with a small second component).
pub fn salinas_like(n: usize, bands: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed);
    let classes = 16;
    // smooth endmembers: random walk smoothed by a 9-tap moving average
    let mut endmembers = vec![vec![0.0; bands]; classes];
    for e in endmembers.iter_mut() {
        let mut walk = vec![0.0; bands];
        let mut acc: f64 = rng.range(0.3, 0.7);
        for w in walk.iter_mut() {
            acc += 0.05 * rng.normal();
            *w = acc;
        }
        for b in 0..bands {
            let lo = b.saturating_sub(4);
            let hi = (b + 5).min(bands);
            e[b] = walk[lo..hi].iter().sum::<f64>() / (hi - lo) as f64;
        }
    }
    let mut ds = Dataset::zeros(n, bands);
    for i in 0..n {
        let c = rng.below(classes);
        let c2 = rng.below(classes);
        let alpha = rng.range(0.85, 1.15); // illumination scaling
        let mix = rng.range(0.0, 0.1); // small second endmember
        let p = ds.point_mut(i);
        for b in 0..bands {
            p[b] = alpha * endmembers[c][b]
                + mix * endmembers[c2][b]
                + 0.01 * rng.normal();
        }
    }
    ds
}

/// Light-field-like patches (paper §V-C-f: 85,265 patches of dim 400 from
/// a 4-D light field). Patches live near a low-dimensional manifold:
/// each patch is a shifted/oriented smooth edge sampled on a 4×4 spatial ×
/// 5×5 angular grid, parameterized by (orientation, offset, parallax).
pub fn lightfield_like(n: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed);
    let dim = 4 * 4 * 5 * 5; // 400
    let mut ds = Dataset::zeros(n, dim);
    for i in 0..n {
        let theta = rng.range(0.0, std::f64::consts::PI);
        let offset = rng.range(-2.0, 2.0);
        let parallax = rng.range(-0.5, 0.5);
        let contrast = rng.range(0.5, 1.5);
        let (ct, st) = (theta.cos(), theta.sin());
        let p = ds.point_mut(i);
        let mut idx = 0;
        for u in 0..5 {
            for v in 0..5 {
                // angular coordinates shift the edge by parallax
                let du = (u as f64 - 2.0) * parallax;
                let dv = (v as f64 - 2.0) * parallax;
                for x in 0..4 {
                    for y in 0..4 {
                        let xx = x as f64 - 1.5 + du;
                        let yy = y as f64 - 1.5 + dv;
                        let d = ct * xx + st * yy - offset;
                        // smooth edge profile
                        p[idx] = contrast * (d / 0.75).tanh() + 0.02 * rng.normal();
                        idx += 1;
                    }
                }
            }
        }
    }
    ds
}

/// Tiny-Images-like (paper §V-D-h: up to 4M one-channel 32×32 images).
/// Images are random smooth textures: a few low-frequency 2-D cosines with
/// random phase/amplitude plus noise — giving the heavy low-frequency
/// spectral concentration of natural tiny images. `dim` defaults to 1024
/// in the callers; smaller dims keep scaled runs cheap.
pub fn tiny_images_like(n: usize, side: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed);
    let dim = side * side;
    let modes = 6;
    let mut ds = Dataset::zeros(n, dim);
    for i in 0..n {
        // random low-frequency mixture
        let mut freqs = Vec::with_capacity(modes);
        for _ in 0..modes {
            freqs.push((
                rng.below(3) as f64 + 1.0,
                rng.below(3) as f64 + 1.0,
                rng.range(0.0, 2.0 * std::f64::consts::PI),
                rng.range(0.2, 1.0),
            ));
        }
        let base = rng.range(0.2, 0.8);
        let p = ds.point_mut(i);
        for x in 0..side {
            for y in 0..side {
                let mut v = base;
                for &(fx, fy, phase, amp) in &freqs {
                    v += amp
                        * ((fx * x as f64 + fy * y as f64)
                            * std::f64::consts::PI
                            / side as f64
                            + phase)
                            .cos()
                        / modes as f64;
                }
                p[x * side + y] = v + 0.02 * rng.normal();
            }
        }
    }
    ds
}

/// Union of k random low-dimensional subspaces in R^dim — the canonical
/// sparse-subspace-clustering workload ([30], SEED §II-E): point i lies on
/// subspace i mod k, with small ambient noise. Self-expressive methods
/// separate these clusters because each point is sparsely representable by
/// points from its own subspace only.
pub fn union_of_subspaces(
    n: usize,
    dim: usize,
    k: usize,
    sub_dim: usize,
    noise: f64,
    seed: u64,
) -> Dataset {
    assert!(sub_dim <= dim);
    let mut rng = Pcg64::new(seed);
    // random orthonormal-ish bases (Gaussian — near-orthogonal in high dim)
    let mut bases = vec![vec![0.0; sub_dim * dim]; k];
    for b in bases.iter_mut() {
        rng.fill_normal(b);
        let norm = (dim as f64).sqrt();
        for x in b.iter_mut() {
            *x /= norm;
        }
    }
    let mut ds = Dataset::zeros(n, dim);
    for i in 0..n {
        let b = &bases[i % k];
        let p = ds.point_mut(i);
        for r in 0..sub_dim {
            let w = rng.normal();
            let row = &b[r * dim..(r + 1) * dim];
            for (x, &bv) in p.iter_mut().zip(row) {
                *x += w * bv;
            }
        }
        for x in p.iter_mut() {
            *x += noise * rng.normal();
        }
    }
    ds
}

/// Shared machinery: k classes, each with a prototype and an r-dimensional
/// within-class subspace; points = prototype + subspace deformation + noise.
fn low_rank_classes(
    n: usize,
    dim: usize,
    classes: usize,
    class_rank: usize,
    within_scale: f64,
    noise: f64,
    seed: u64,
) -> Dataset {
    let mut rng = Pcg64::new(seed);
    let mut prototypes = vec![vec![0.0; dim]; classes];
    for p in prototypes.iter_mut() {
        rng.fill_normal(p);
        // smooth the prototype a little (images are smooth)
        for d in 1..dim {
            p[d] = 0.6 * p[d] + 0.4 * p[d - 1];
        }
    }
    let mut bases = vec![vec![0.0; class_rank * dim]; classes];
    for b in bases.iter_mut() {
        rng.fill_normal(b);
    }
    let mut ds = Dataset::zeros(n, dim);
    for i in 0..n {
        let c = i % classes;
        let p = ds.point_mut(i);
        p.copy_from_slice(&prototypes[c]);
        for r in 0..class_rank {
            let w = within_scale * rng.normal() / (class_rank as f64).sqrt();
            let row = &bases[c][r * dim..(r + 1) * dim];
            for (x, &b) in p.iter_mut().zip(row) {
                *x += w * b;
            }
        }
        for x in p.iter_mut() {
            *x += noise * rng.normal();
        }
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_moons_shape_and_determinism() {
        let a = two_moons(100, 0.05, 42);
        let b = two_moons(100, 0.05, 42);
        assert_eq!(a, b);
        assert_eq!(a.n(), 100);
        assert_eq!(a.dim(), 2);
        // points near the two unit circles: radius from either center ≈ 1
        for i in 0..100 {
            let p = a.point(i);
            let r1 = (p[0].powi(2) + p[1].powi(2)).sqrt();
            let r2 = ((p[0] - 1.0).powi(2) + (p[1] - 0.5).powi(2)).sqrt();
            assert!(
                (r1 - 1.0).abs() < 0.3 || (r2 - 1.0).abs() < 0.3,
                "point {i} off-moon"
            );
        }
    }

    #[test]
    fn borg_counts_and_vertices() {
        let ds = borg(3, 5, 0.01, 1);
        assert_eq!(ds.n(), 8 * 5);
        assert_eq!(ds.dim(), 3);
        // every point close to a binary vertex
        for i in 0..ds.n() {
            for &x in ds.point(i) {
                assert!((x - 0.0).abs() < 0.5 || (x - 1.0).abs() < 0.5);
            }
        }
    }

    #[test]
    fn gauss_2d_plus_3d_gram_rank_3() {
        let ds = gauss_2d_plus_3d(30, 30, 2);
        let g = crate::kernels::kernel_matrix(&ds, &crate::kernels::Linear);
        assert_eq!(crate::linalg::eig::psd_rank(&g, 1e-9), 3);
    }

    #[test]
    fn abalone_like_positive_correlated() {
        let ds = abalone_like(500, 3);
        assert_eq!(ds.dim(), 8);
        // feature 0 (length) and 3 (whole weight) strongly correlated
        let (mut sx, mut sy, mut sxx, mut syy, mut sxy) = (0.0, 0.0, 0.0, 0.0, 0.0);
        let n = ds.n() as f64;
        for i in 0..ds.n() {
            let p = ds.point(i);
            sx += p[0];
            sy += p[3];
            sxx += p[0] * p[0];
            syy += p[3] * p[3];
            sxy += p[0] * p[3];
        }
        let corr = (n * sxy - sx * sy)
            / ((n * sxx - sx * sx).sqrt() * (n * syy - sy * sy).sqrt());
        assert!(corr > 0.8, "corr {corr}");
    }

    #[test]
    fn mnist_like_is_low_rank() {
        // 10 classes × rank-6 subspaces + prototype ⇒ Gram spectrum decays
        let ds = mnist_like(200, 64, 4);
        let g = crate::kernels::kernel_matrix(&ds, &crate::kernels::Linear);
        let eig = crate::linalg::sym_eig(&g);
        let total: f64 = eig.vals.iter().filter(|&&v| v > 0.0).sum();
        let top: f64 = eig.vals.iter().take(80).filter(|&&v| v > 0.0).sum();
        assert!(top / total > 0.95, "top-80 mass {}", top / total);
    }

    #[test]
    fn salinas_like_smooth_spectra() {
        let ds = salinas_like(50, 64, 5);
        // adjacent-band differences much smaller than the value scale
        for i in 0..50 {
            let p = ds.point(i);
            let scale: f64 =
                p.iter().map(|x| x.abs()).sum::<f64>() / p.len() as f64;
            let rough: f64 = p.windows(2).map(|w| (w[1] - w[0]).abs()).sum::<f64>()
                / (p.len() - 1) as f64;
            assert!(rough < 0.3 * scale.max(0.1), "rough {rough} scale {scale}");
        }
    }

    #[test]
    fn lightfield_dim_400() {
        let ds = lightfield_like(10, 6);
        assert_eq!(ds.dim(), 400);
    }

    #[test]
    fn tiny_images_shape() {
        let ds = tiny_images_like(10, 8, 7);
        assert_eq!(ds.dim(), 64);
        // values roughly in a bounded intensity range
        for i in 0..10 {
            for &x in ds.point(i) {
                assert!((-2.0..3.0).contains(&x));
            }
        }
    }

    #[test]
    fn union_of_subspaces_rank_structure() {
        // k subspaces of dim r ⇒ Gram rank ≤ k·r (plus noise floor)
        let ds = union_of_subspaces(120, 24, 4, 3, 0.0, 8);
        let g = crate::kernels::kernel_matrix(&ds, &crate::kernels::Linear);
        assert_eq!(crate::linalg::eig::psd_rank(&g, 1e-9), 12);
    }

    #[test]
    fn gaussian_clusters_deterministic() {
        let a = gaussian_clusters(60, 4, 5, 0.3, 9);
        let b = gaussian_clusters(60, 4, 5, 0.3, 9);
        assert_eq!(a, b);
    }

    /// `dim_by_name`'s predictions must match what `by_name` builds, for
    /// every name, so pre-allocation validation can trust it.
    #[test]
    fn dim_by_name_matches_by_name() {
        for name in [
            "two-moons",
            "abalone",
            "borg",
            "mnist",
            "salinas",
            "lightfield",
            "tiny-images",
        ] {
            for dim in [0usize, 32] {
                let predicted = dim_by_name(name, dim).unwrap();
                let built = by_name(name, 300, dim, 0.05, 3).unwrap();
                assert_eq!(built.dim(), predicted, "{name} dim={dim}");
            }
        }
        assert!(by_name("nope", 10, 0, 0.05, 1).is_none());
        assert!(dim_by_name("nope", 0).is_none());
    }
}
