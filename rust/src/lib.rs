//! # oASIS — Adaptive Column Sampling for Kernel Matrix Approximation
//!
//! A production-quality reproduction of *oASIS: Adaptive Column Sampling for
//! Kernel Matrix Approximation* (Patel, Goldstein, Dyer, Mirhoseini,
//! Baraniuk; stat.ML 2015) as a three-layer Rust + JAX/Pallas stack:
//!
//! * **L3 (this crate)** — the coordination layer: the sequential oASIS
//!   selector, the distributed oASIS-P leader/worker runtime
//!   ([`coordinator`]), every baseline sampler the paper compares against
//!   ([`sampling`]), Nyström assembly and error estimation ([`nystrom`]),
//!   dataset generators ([`data`]) and dense linear algebra ([`linalg`]).
//! * **L2/L1 (python/, build time only)** — the per-iteration compute graph
//!   (Δ-scoring, Gaussian kernel columns, Eq. 5/6 rank-1 updates) written in
//!   JAX calling Pallas kernels, AOT-lowered to HLO text artifacts.
//! * **Runtime bridge** ([`runtime`]) — loads those artifacts through the
//!   PJRT CPU client (`xla` crate) and serves them on the Rust hot path;
//!   every op also has a native Rust fallback so the library is fully
//!   functional without artifacts.
//!
//! ## Quickstart
//!
//! ```no_run
//! use oasis::data::generators::two_moons;
//! use oasis::kernels::Gaussian;
//! use oasis::sampling::{oasis::Oasis, ColumnSampler};
//! use oasis::nystrom::error::relative_frobenius_error;
//!
//! let ds = two_moons(2_000, 0.05, 42);
//! let kernel = Gaussian::with_sigma_fraction(&ds, 0.05);
//! let oracle = oasis::sampling::ImplicitOracle::new(&ds, &kernel);
//! let approx = Oasis::new(450, 10, 1e-12, 7).sample(&oracle).unwrap();
//! let err = relative_frobenius_error(&oracle, &approx);
//! println!("relative Frobenius error: {err:.3e}");
//! ```

pub mod bench_support;
pub mod coordinator;
pub mod data;
pub mod kernels;
pub mod linalg;
pub mod nystrom;
pub mod runtime;
pub mod sampling;
pub mod seed;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
