//! # oASIS — Adaptive Column Sampling for Kernel Matrix Approximation
//!
//! A production-quality reproduction of *oASIS: Adaptive Column Sampling for
//! Kernel Matrix Approximation* (Patel, Goldstein, Dyer, Mirhoseini,
//! Baraniuk; stat.ML 2015) as a three-layer Rust + JAX/Pallas stack:
//!
//! * **L3 (this crate)** — the coordination layer: the sequential oASIS
//!   selector, the distributed oASIS-P leader/worker runtime
//!   ([`coordinator`] — in-process channels or a fault-tolerant framed-TCP
//!   transport for true multi-process fleets),
//!   every baseline sampler the paper compares against
//!   ([`sampling`]), Nyström assembly and error estimation ([`nystrom`]),
//!   dataset generators ([`data`]), dense linear algebra ([`linalg`]),
//!   the spec-driven run pipeline ([`engine`]) that the CLI, the
//!   HTTP server ([`server`]) and the coordinator all resolve their runs
//!   through, and the downstream-task layer ([`tasks`]) that turns an
//!   approximation into regression, embedding, and clustering answers.
//! * **L2/L1 (python/, build time only)** — the per-iteration compute graph
//!   (Δ-scoring, Gaussian kernel columns, Eq. 5/6 rank-1 updates) written in
//!   JAX calling Pallas kernels, AOT-lowered to HLO text artifacts.
//! * **Runtime bridge** ([`runtime`]) — loads those artifacts through the
//!   PJRT CPU client (`xla` crate, behind the `pjrt` feature) and serves
//!   them on the Rust hot path; every op also has a native Rust fallback so
//!   the library is fully functional without artifacts.
//!
//! ## Quickstart: stepwise sessions
//!
//! Selection is sequential and cheap per step (paper §III), and the API
//! exposes that directly: open a [`SamplerSession`](sampling::SamplerSession),
//! drive it under any combination of stopping criteria — column budget,
//! Δ tolerance, estimated-error target, wall-clock deadline — and assemble
//! a [`NystromApprox`](nystrom::NystromApprox) whenever you like. Sessions
//! are resumable: ask for more columns later and the index set extends.
//!
//! ```no_run
//! use oasis::data::generators::two_moons;
//! use oasis::kernels::Gaussian;
//! use oasis::nystrom::error::relative_frobenius_error;
//! use oasis::sampling::oasis::Oasis;
//! use oasis::sampling::{
//!     run_to_completion, ImplicitOracle, SamplerSession, StoppingCriterion,
//!     StoppingRule,
//! };
//!
//! let ds = two_moons(2_000, 0.05, 42);
//! let kernel = Gaussian::with_sigma_fraction(&ds, 0.05);
//! let oracle = ImplicitOracle::new(&ds, &kernel);
//!
//! // grow until the estimated error reaches 1e-3, capped at 450 columns
//! let mut session = Oasis::new(450, 10, 1e-12, 7).session(&oracle).unwrap();
//! let rule = StoppingRule::budget(450)
//!     .with(StoppingCriterion::ErrorBelow(1e-3));
//! let reason = run_to_completion(&mut session, &rule).unwrap();
//! println!("stopped after {} columns ({reason:?})", session.k());
//!
//! // snapshot, keep the session, resume with a larger budget later
//! let approx = session.snapshot().unwrap();
//! let err = relative_frobenius_error(&oracle, &approx);
//! println!("relative Frobenius error: {err:.3e}");
//! run_to_completion(&mut session, &StoppingRule::budget(600)).unwrap();
//! ```
//!
//! The one-shot API is still there — `Oasis::new(450, 10, 1e-12, 7)
//! .sample(&oracle)` — as a thin adapter over the same session machinery,
//! so both paths select bit-identical column sequences.
//!
//! ## Quickstart: serving
//!
//! Because sessions are resumable, an approximation can be hosted in a
//! long-lived process and *grown per request* instead of recomputed:
//! `oasis serve` (the [`server`] module) exposes a registry of named,
//! concurrent sessions over a dependency-free HTTP/1.1 + JSON protocol —
//! create a session, step it (synchronously or on its background actor
//! thread), snapshot the current Nyström factors mid-run, answer
//! out-of-sample extension queries against the live snapshot, and finish
//! it for the final factors.
//!
//! ```bash
//! oasis serve --port 7437 &
//! curl -X POST localhost:7437/sessions -d '{
//!   "name": "m", "dataset": {"generator": "two-moons", "n": 2000},
//!   "method": "oasis", "max_cols": 450}'
//! curl -X POST localhost:7437/sessions/m/step -d '{"steps": 50, "target_err": 1e-3}'
//! curl localhost:7437/sessions/m/snapshot
//! curl -X POST localhost:7437/sessions/m/query -d '{"points": [[0.5, 0.2]], "targets": [0]}'
//! curl localhost:7437/metrics
//! curl -X POST localhost:7437/sessions/m/finish
//! ```
//!
//! The server is built for production prediction traffic: a fixed-size
//! connection thread pool with a bounded accept queue (`--threads`,
//! `--queue`; overflow is shed with a one-shot 503), HTTP/1.1
//! keep-alive so clients pay one TCP handshake per connection rather
//! than per request, optional global/per-IP request rate caps
//! (`--max-rps`, `--max-rps-per-ip` → 429), and a graceful drain that
//! lets in-flight requests finish on shutdown (`--drain-ms`). Task
//! endpoints accept a batch of predict points per request — served as
//! one B×k kernel block plus one blocked product, bit-identical in f64
//! to single-point calls — plus multi-output labels and an opt-in f32
//! serving mode; per-model predict-latency and batch-size histograms
//! surface under `"predict"` in `/metrics`. `oasis bench-serve` load-
//! generates that traffic against a live (or self-hosted) server and
//! reports the single-point vs. batched RPS trajectory.
//!
//! The full endpoint/payload reference is in the [`server`] module docs;
//! `examples/serve_client.rs` drives the same lifecycle from Rust, and
//! `examples/batch_serving.rs` the keep-alive + batched multi-output
//! predict path.
//!
//! ## Quickstart: persistence
//!
//! Finished (or snapshot) approximations can outlive their process: the
//! artifact store ([`nystrom::store`]) serializes indices, factors, the
//! selected points, and the resolved kernel to a checksummed on-disk
//! format, and the loaded artifact answers out-of-sample extension
//! queries **without** the original dataset or oracle. Datasets load
//! from CSV or binary matrix files ([`data::loader`]), whole or as
//! per-worker shards. End to end:
//!
//! ```bash
//! oasis approximate --data train.csv --cols 200 --save model.oasis
//! oasis query --load model.oasis --points "0.5,0.2" --targets 0,17
//! # …or over HTTP: POST /sessions/{name}/save, POST /artifacts/load,
//! #                POST /artifacts/{name}/query
//! ```
//!
//! `examples/persist_and_query.rs` drives the same round trip in Rust.
//!
//! ## Quickstart: downstream tasks
//!
//! An approximation is a means, not an end: the [`tasks`] layer runs
//! the workloads the paper motivates — kernel ridge regression
//! ([`tasks::krr`]), kernel PCA ([`tasks::kpca`]), and spectral
//! clustering ([`tasks::cluster`]) — directly on the rank-k factors in
//! O(nk²), never materializing the n×n matrix. Models live in the
//! k-dimensional landmark space, so prediction is dataset-free: a
//! loaded artifact (optionally carrying the fitted model in its `task`
//! section) answers with only its k stored points.
//!
//! ```no_run
//! use oasis::data::generators::two_moons;
//! use oasis::kernels::Gaussian;
//! use oasis::sampling::oasis::Oasis;
//! use oasis::sampling::{run_to_completion, ImplicitOracle, SamplerSession, StoppingRule};
//! use oasis::tasks::{FittedTask, TaskConfig, TaskKind};
//!
//! let ds = two_moons(2_000, 0.05, 42);
//! let kernel = Gaussian::with_sigma_fraction(&ds, 0.1);
//! let oracle = ImplicitOracle::new(&ds, &kernel);
//! let mut session = Oasis::new(200, 10, 1e-12, 7).session(&oracle).unwrap();
//! run_to_completion(&mut session, &StoppingRule::budget(200)).unwrap();
//! let approx = session.snapshot().unwrap();
//!
//! // labels are output-major columns: one Vec per output. Pass several
//! // columns and the m outputs share one factorization (multi-output KRR).
//! let mut cfg = TaskConfig::new(TaskKind::Krr);
//! cfg.labels = Some(vec![(0..2_000).map(|i| (i % 2) as f64).collect()]);
//! let fit = FittedTask::fit(&approx, &cfg).unwrap();
//! let selected = ds.select(&approx.indices);
//! // one call, many points: the batch is evaluated as a single B×k
//! // kernel block + one blocked product, bit-identical to per-point calls
//! let pred = fit.model.predict(&kernel, &selected, &[vec![0.5, 0.2], vec![-0.5, 0.4]]).unwrap();
//! println!("{pred:?}");
//! ```
//!
//! ```bash
//! oasis task --task krr --data train.csv --labels y.csv --cols 200 \
//!     --save model.oasis                       # sample → fit → save
//! oasis task --task krr --load model.oasis --predict new.csv   # no labels
//! # …or over HTTP: POST /sessions/{name}/task, POST /artifacts/{name}/task
//! ```
//!
//! `examples/krr_pipeline.rs` drives sample → save → fit → predict.
//!
//! ## Quickstart: spec-driven runs
//!
//! Every front end resolves its runs through the same [`engine`] layer:
//! a typed [`RunSpec`](engine::RunSpec) (dataset source, kernel, method,
//! stopping criteria, optional warm-start artifact, optional sharded
//! worker reads) resolved by a
//! [`SessionBuilder`](engine::SessionBuilder) into oracle + session —
//! so the CLI, the server, and the oASIS-P coordinator select
//! bit-identical column sequences from the same spec. Saved artifacts
//! can *warm-start* new sessions (`approximate --resume-from`, server
//! create option `"warm_start"`), and oASIS-P workers can each read only
//! their own shard byte range of a binary dataset file
//! (`parallel --shard-reads`, server create option `"shard_reads"`).
//!
//! ## Quickstart: multi-node oASIS-P
//!
//! The coordinator speaks through a [`Transport`](coordinator::Transport):
//! the same leader drives in-process channel workers (the default) or
//! separate worker *processes* over a length-framed, checksummed TCP
//! protocol ([`coordinator::net`]) — same messages, bit-identical
//! selections at the default merge width. Workers join a listening
//! leader, shard-read their own byte range of the dataset file, answer
//! argmax/column requests, and send heartbeats; if one dies mid-run the
//! leader re-shards its rows onto the survivors and finishes the run.
//! A SQUEAK-style merge (`--merge-batch B`) admits up to B candidates
//! per gather round when fewer synchronization rounds matter more than
//! exact selection order.
//!
//! ```bash
//! oasis parallel --data train.bin --shard-reads --sigma 0.5 \
//!     --workers 2 --cols 200 --listen 127.0.0.1:0   # prints join addr
//! oasis worker --join 127.0.0.1:PORT                # run once per node
//! oasis worker --join 127.0.0.1:PORT
//! ```
//!
//! Per-worker counters (columns served, argmax rounds, bytes on the
//! wire, heartbeat age) surface in the run report and, for hosted
//! sessions, under `"workers"` in the server's stats/metrics endpoints.
//!
//! ## Quickstart: observability
//!
//! The [`obs`] layer answers *where does the time go*. Every hot path —
//! sampling step phases (score scan, column fetch, factor update),
//! engine resolve, task fit/predict, coordinator gather/arbitrate/
//! reshard rounds, per-frame wire bytes — carries trace guards that are
//! free until enabled (one atomic load). `--trace FILE` on
//! `approximate`, `parallel`, and `task` records a run and writes a
//! Chrome `trace_event` file; load it at `chrome://tracing` or
//! <https://ui.perfetto.dev> to see the nested per-phase spans, and
//! read the per-phase timing table (count, total, p50/p99) the CLI
//! prints alongside:
//!
//! ```bash
//! oasis approximate --dataset two-moons --n 2000 --cols 200 --trace out.json
//! # phase                 count      total        p50        p99
//! # score_scan              190     1.52s      7.81ms     9.21ms
//! # column_fetch            190   310.20ms     1.58ms     2.11ms
//! # factor_update           190   120.93ms   602.11µs   811.90µs
//! ```
//!
//! Library users call [`obs::trace::enable`], run anything, then
//! [`obs::trace::drain`] for the same exports
//! (`examples/trace_phases.rs` walks a trace by hand).
//!
//! Three pillars cover the whole fleet:
//!
//! 1. **Structured logging** ([`obs::log`]) — leveled JSON-lines (or
//!    plain-text) records on stderr, switched by `--log-level` /
//!    `--log-json` on `serve`, `parallel`, and `worker`. Every HTTP
//!    request carries an `X-Request-Id` (client-supplied ids are
//!    honored, otherwise one is generated), echoed on the response and
//!    attached to the request log line, so a client-reported failure
//!    greps straight to its server-side record.
//! 2. **Metrics** — the server's `/metrics` JSON report and its
//!    Prometheus text exposition ([`obs::prom`]): every JSON counter,
//!    per-endpoint request-duration histograms with p50/p90/p99, live
//!    per-worker oASIS-P gauges, and per-session convergence gauges
//!    (`oasis_session_error_estimate`, `oasis_session_best_score`) from
//!    `GET /metrics?format=prometheus`. Per-step *convergence
//!    telemetry* rides alongside: each hosted session keeps a bounded
//!    trajectory ring (step, k, error estimate, score, step µs) served
//!    by `GET /sessions/{name}/trajectory` and summarized under
//!    `"trajectory"` in `/metrics`; the CLI writes the same series with
//!    `approximate --trajectory FILE` (CSV).
//! 3. **Distributed tracing** — `parallel --trace FILE` merges the
//!    leader's spans with every TCP worker's locally-recorded spans
//!    (shipped leader-ward at run end) into one Chrome trace with a
//!    per-process track per worker; `worker --trace FILE` writes a
//!    worker's own local trace, and a live server records between
//!    `POST /debug/trace` (enable/disable, ring capacity) and
//!    `GET /debug/trace` (drain as Chrome JSON or `?format=jsonl`).
//!    `examples/fleet_trace.rs` builds and merges a fleet trace by
//!    hand. Protocol details live in the [`server`] docs.
//!
//! # Performance
//!
//! The dense linalg core ([`linalg::matrix`]) is cache-blocked: `matmul`
//! tiles its outer i/j loops (4-row quads × 256-column blocks),
//! `t_matmul` streams `AᵀB` in 32-row tiles without materializing the
//! transpose, and the dedicated Gram kernel [`linalg::Mat::syrk`]
//! computes `AᵀA` at half the flops and mirrors the triangle — the path
//! under [`nystrom::nystrom_factor`] eigensolves and the KRR normal
//! equations. The oASIS step recurrence runs as one fused sweep
//! ([`sampling::oasis::fused_step_update`]), and the implicit oracle
//! batches kernel columns through [`kernels::Kernel::eval_rows`] — one
//! virtual dispatch per contiguous row block instead of one per entry.
//! Outer blocks thread through [`util::parallel`].
//!
//! One constraint governs all of it: **blocking may reorder which
//! output element is computed next, never the k-term accumulation order
//! within an element** (single accumulator, ascending k). That keeps
//! every kernel bit-identical to its naive reference, so selection
//! sequences and stored-artifact factors are byte-stable across kernel
//! rewrites — asserted by property tests, a naive in-test
//! reimplementation of the whole selection loop, and the paired
//! benches in `benches/perf.rs`, whose speedup ratios CI's bench-gate
//! job diffs against the committed `BENCH_main.json` (≥25% regressions
//! fail; baseline-refresh workflow in the perf.rs header).

pub mod bench_support;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod error;
pub mod kernels;
pub mod linalg;
pub mod nystrom;
pub mod obs;
pub mod runtime;
pub mod sampling;
pub mod seed;
pub mod server;
pub mod tasks;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = std::result::Result<T, error::Error>;
