//! PJRT execution of AOT artifacts (adapted from /opt/xla-example/load_hlo).
//!
//! One [`Executor`] owns a PJRT CPU client and the compiled executables for
//! every artifact it has loaded. HLO *text* is the interchange format — see
//! python/compile/aot.py for why protos are rejected.
//!
//! The real implementation needs the `xla` crate (PJRT bindings + the XLA
//! C library), which cannot be vendored into the offline build; it is
//! gated behind the `pjrt` cargo feature. Without the feature this module
//! compiles a stub with the same API whose constructor reports PJRT as
//! unavailable — [`Accel::try_default`](super::Accel::try_default) then
//! returns `None` and every caller takes its native fallback, so the
//! library is fully functional either way.

use super::artifacts::Artifact;
use crate::Result;

#[cfg(feature = "pjrt")]
mod imp {
    use super::Artifact;
    use crate::anyhow;
    use crate::error::Context;
    use crate::Result;
    use std::collections::BTreeMap;

    /// A loaded PJRT client plus compiled artifact executables.
    pub struct Executor {
        client: xla::PjRtClient,
        compiled: BTreeMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Executor {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<Executor> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Executor { client, compiled: BTreeMap::new() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile one artifact (idempotent per name).
        pub fn load(&mut self, artifact: &Artifact) -> Result<()> {
            if self.compiled.contains_key(&artifact.name) {
                return Ok(());
            }
            let path = artifact
                .path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path"))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {}", artifact.name))?;
            self.compiled.insert(artifact.name.clone(), exe);
            Ok(())
        }

        pub fn is_loaded(&self, name: &str) -> bool {
            self.compiled.contains_key(name)
        }

        /// Execute a loaded artifact on f32 inputs. Each input is
        /// (data, dims); the module was lowered with `return_tuple=True`,
        /// so the result is a tuple whose elements are returned in order
        /// as f32 vectors.
        pub fn run_f32(
            &self,
            name: &str,
            inputs: &[(&[f32], &[i64])],
        ) -> Result<Vec<Vec<f32>>> {
            let exe = self
                .compiled
                .get(name)
                .ok_or_else(|| anyhow!("artifact {name} not loaded"))?;
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, dims) in inputs {
                let lit = if dims.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    xla::Literal::vec1(data).reshape(dims)?
                };
                literals.push(lit);
            }
            let result = exe.execute::<xla::Literal>(&literals)?;
            let out = result[0][0].to_literal_sync()?;
            let parts = out.to_tuple()?;
            let mut vecs = Vec::with_capacity(parts.len());
            for p in parts {
                // outputs may be f32 or i32 (argmax index) — convert to f32
                let v: Vec<f32> = match p.to_vec::<f32>() {
                    Ok(v) => v,
                    Err(_) => p
                        .convert(xla::PrimitiveType::F32)?
                        .to_vec::<f32>()
                        .context("converting output to f32")?,
                };
                vecs.push(v);
            }
            Ok(vecs)
        }
    }

    impl std::fmt::Debug for Executor {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Executor")
                .field("platform", &self.client.platform_name())
                .field("loaded", &self.compiled.keys().collect::<Vec<_>>())
                .finish()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::Artifact;
    use crate::bail;
    use crate::Result;

    const UNAVAILABLE: &str =
        "PJRT support not compiled in — rebuild with `--features pjrt` \
         (requires the `xla` crate and the XLA C library)";

    /// Stub executor: same API, every operation reports PJRT unavailable.
    pub struct Executor {
        _private: (),
    }

    impl Executor {
        pub fn cpu() -> Result<Executor> {
            bail!("{UNAVAILABLE}")
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn load(&mut self, _artifact: &Artifact) -> Result<()> {
            bail!("{UNAVAILABLE}")
        }

        pub fn is_loaded(&self, _name: &str) -> bool {
            false
        }

        pub fn run_f32(
            &self,
            _name: &str,
            _inputs: &[(&[f32], &[i64])],
        ) -> Result<Vec<Vec<f32>>> {
            bail!("{UNAVAILABLE}")
        }
    }

    impl std::fmt::Debug for Executor {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Executor").field("pjrt", &"disabled").finish()
        }
    }
}

pub use imp::Executor;

// Re-assert the public contract is identical across both builds.
const _: fn() -> Result<Executor> = Executor::cpu;

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = Executor::cpu().err().expect("stub must not create");
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }
}
