//! PJRT runtime bridge: loads the AOT HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the `xla` crate's CPU
//! client from the Rust hot path. Every op has a native fallback
//! ([`accel`] dispatches), so the library works without artifacts.

pub mod accel;
pub mod artifacts;
pub mod executor;

pub use accel::Accel;
pub use artifacts::{Artifact, Manifest};
pub use executor::Executor;
