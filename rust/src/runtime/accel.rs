//! Artifact-accelerated oASIS: the L3 hot path backed by the AOT-lowered
//! L2/L1 modules (Δ-scoring, Gaussian kernel columns) with zero-padding to
//! the fixed artifact shapes, and a native fallback when no artifact fits.
//!
//! The padding contract (tested in python/tests and here): C is padded to
//! (n_pad × l_pad) row-major f32 with zeros beyond (n, k), R to
//! (l_pad × n_pad); zero padding leaves Δ = d − colsum(C∘R) unchanged, so
//! one artifact serves every iteration k ≤ l_pad.

use super::{Executor, Manifest};
use crate::sampling::{ColumnOracle, ColumnSampler, SelectionTrace};
use crate::linalg::Mat;
use crate::nystrom::NystromApprox;
use crate::util::{rng::Pcg64, timing::Stopwatch};
use crate::Result;
use anyhow::{anyhow, bail};
use std::path::Path;

/// Loaded manifest + executor, shared by accelerated ops.
pub struct Accel {
    pub manifest: Manifest,
    pub executor: Executor,
}

impl Accel {
    /// Load from an artifact directory.
    pub fn load(dir: &Path) -> Result<Accel> {
        let manifest = Manifest::load(dir)?;
        let executor = Executor::cpu()?;
        Ok(Accel { manifest, executor })
    }

    /// Load from `$OASIS_ARTIFACTS` / `./artifacts`; `None` if unavailable
    /// (missing artifacts are not an error — native fallback).
    pub fn try_default() -> Option<Accel> {
        Accel::load(&Manifest::default_dir()).ok()
    }

    /// Gaussian kernel columns through the `gaussian_columns` artifact:
    /// (n × m) block against (k × m) selected points. Falls back to an
    /// error if no artifact bucket fits; callers dispatch natively then.
    pub fn gaussian_columns(
        &mut self,
        z_blk: &[f64],
        n: usize,
        z_sel: &[f64],
        k: usize,
        m: usize,
        inv_sigma_sq: f64,
    ) -> Result<Vec<f64>> {
        let art = self
            .manifest
            .best_fit("gaussian_columns", n, &[("k", k), ("m", m)])
            .ok_or_else(|| anyhow!("no gaussian_columns artifact for n={n} k={k} m={m}"))?
            .clone();
        let (n_pad, k_pad, m_pad) = (
            art.dim("n").unwrap(),
            art.dim("k").unwrap(),
            art.dim("m").unwrap(),
        );
        self.executor.load(&art)?;
        // zero-pad inputs
        let mut zb = vec![0.0f32; n_pad * m_pad];
        for i in 0..n {
            for d in 0..m {
                zb[i * m_pad + d] = z_blk[i * m + d] as f32;
            }
        }
        let mut zs = vec![0.0f32; k_pad * m_pad];
        for i in 0..k {
            for d in 0..m {
                zs[i * m_pad + d] = z_sel[i * m + d] as f32;
            }
        }
        let gamma = [inv_sigma_sq as f32];
        let outs = self.executor.run_f32(
            &art.name,
            &[
                (&zb, &[n_pad as i64, m_pad as i64]),
                (&zs, &[k_pad as i64, m_pad as i64]),
                (&gamma, &[]),
            ],
        )?;
        let cols = &outs[0]; // (n_pad, k_pad)
        let mut out = vec![0.0f64; n * k];
        for i in 0..n {
            for j in 0..k {
                out[i * k + j] = cols[i * k_pad + j] as f64;
            }
        }
        Ok(out)
    }
}

/// oASIS with the Δ-scoring step served by the PJRT artifact. Maintains
/// the paper's R matrix natively (f64) plus f32 mirrors in the artifact's
/// padded layout; selection sequences match the native sampler to f32
/// precision (tested in rust/tests/runtime_pjrt.rs).
pub struct PjrtOasis {
    pub max_cols: usize,
    pub init_cols: usize,
    pub tol: f64,
    pub seed: u64,
}

impl PjrtOasis {
    pub fn new(max_cols: usize, init_cols: usize, tol: f64, seed: u64) -> Self {
        PjrtOasis { max_cols, init_cols, tol, seed }
    }

    /// Run selection using `accel` for scoring.
    pub fn sample_with(
        &self,
        accel: &mut Accel,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)> {
        let sw = Stopwatch::start();
        let n = oracle.n();
        let l = self.max_cols.min(n);
        let art = accel
            .manifest
            .best_fit("delta_scores", n, &[("l", l)])
            .ok_or_else(|| anyhow!("no delta_scores artifact for n={n} l={l}"))?
            .clone();
        let n_pad = art.dim("n").unwrap();
        let l_pad = art.dim("l").unwrap();
        accel.executor.load(&art)?;

        let d = oracle.diag();
        let tol = crate::sampling::effective_tol(self.tol, &d);
        let mut d32 = vec![0.0f32; n_pad];
        for i in 0..n {
            d32[i] = d[i] as f32;
        }

        // native f64 state (C column-major, W⁻¹ strided, R row-major)
        let mut c: Vec<f64> = Vec::with_capacity(l * n);
        let mut winv = vec![0.0f64; l * l];
        let mut r = vec![0.0f64; l * n];
        // f32 mirrors in artifact layout
        let mut c32 = vec![0.0f32; n_pad * l_pad];
        let mut r32 = vec![0.0f32; l_pad * n_pad];

        // --- seed (same stream/rejection as the native sampler) ---
        let mut rng = Pcg64::new(self.seed);
        let k0 = self.init_cols.min(l);
        let mut lambda: Vec<usize>;
        loop {
            let cand = rng.sample_without_replacement(n, k0);
            c.clear();
            c.resize(k0 * n, 0.0);
            for (t, &j) in cand.iter().enumerate() {
                oracle.column_into(j, &mut c[t * n..(t + 1) * n]);
            }
            let mut w = Mat::zeros(k0, k0);
            for (ti, &i) in cand.iter().enumerate() {
                for tj in 0..k0 {
                    *w.at_mut(ti, tj) = c[tj * n + i];
                }
            }
            if let Some(inv) = crate::linalg::inverse(&w) {
                let cond = inv.max_abs() * w.max_abs();
                if cond.is_finite() && cond <= 1e12 {
                    for i in 0..k0 {
                        for j in 0..k0 {
                            winv[i * l + j] = inv.at(i, j);
                        }
                    }
                    lambda = cand;
                    break;
                }
            }
        }
        // R₀ = W₀⁻¹ C₀ᵀ
        let mut k = k0;
        for t in 0..k {
            for i in 0..n {
                let mut acc = 0.0;
                for u in 0..k {
                    acc += winv[t * l + u] * c[u * n + i];
                }
                r[t * n + i] = acc;
            }
        }
        // mirrors
        for t in 0..k {
            mirror_col(&mut c32, &c[t * n..(t + 1) * n], t, l_pad);
            mirror_row(&mut r32, &r[t * n..(t + 1) * n], t, n_pad);
        }

        let mut selected = vec![false; n];
        let mut trace = SelectionTrace::default();
        for &j in &lambda {
            selected[j] = true;
            trace.order.push(j);
            trace.cum_secs.push(sw.secs());
            trace.deltas.push(f64::NAN);
        }

        let mut diff = vec![0.0f64; n];
        while k < l {
            // Δ via the PJRT artifact
            let outs = accel.executor.run_f32(
                &art.name,
                &[
                    (&c32, &[n_pad as i64, l_pad as i64]),
                    (&r32, &[l_pad as i64, n_pad as i64]),
                    (&d32, &[n_pad as i64]),
                ],
            )?;
            let delta32 = &outs[0];
            let mut best = usize::MAX;
            let mut best_abs = -1.0f64;
            for i in 0..n {
                if selected[i] {
                    continue;
                }
                let a = (delta32[i] as f64).abs();
                if a > best_abs {
                    best_abs = a;
                    best = i;
                }
            }
            if best == usize::MAX || best_abs < tol {
                break;
            }
            let s = 1.0 / delta32[best] as f64;
            let mut col = vec![0.0f64; n];
            oracle.column_into(best, &mut col);
            // q = W⁻¹ b
            let mut q = vec![0.0f64; k];
            for t in 0..k {
                let mut acc = 0.0;
                for u in 0..k {
                    acc += winv[t * l + u] * c[u * n + best];
                }
                q[t] = acc;
            }
            // diff = Cq − c_new
            for i in 0..n {
                let mut acc = 0.0;
                for (t, &qt) in q.iter().enumerate() {
                    acc += qt * c[t * n + i];
                }
                diff[i] = acc - col[i];
            }
            // Eq. 5 (W⁻¹)
            for i in 0..k {
                for j in 0..k {
                    winv[i * l + j] += s * q[i] * q[j];
                }
                winv[i * l + k] = -s * q[i];
                winv[k * l + i] = -s * q[i];
            }
            winv[k * l + k] = s;
            // Eq. 6 (R) + mirrors
            for t in 0..k {
                let f = s * q[t];
                let row = &mut r[t * n..(t + 1) * n];
                for (o, &dv) in row.iter_mut().zip(&diff) {
                    *o += f * dv;
                }
                mirror_row(&mut r32, row, t, n_pad);
            }
            for i in 0..n {
                r[k * n + i] = -s * diff[i];
            }
            mirror_row(&mut r32, &r[k * n..(k + 1) * n], k, n_pad);
            c.extend_from_slice(&col);
            mirror_col(&mut c32, &col, k, l_pad);

            selected[best] = true;
            lambda.push(best);
            trace.order.push(best);
            trace.cum_secs.push(sw.secs());
            trace.deltas.push(best_abs);
            k += 1;
        }

        // assemble
        let mut c_mat = Mat::zeros(n, k);
        for t in 0..k {
            for i in 0..n {
                c_mat.data[i * k + t] = c[t * n + i];
            }
        }
        let mut w_mat = Mat::zeros(k, k);
        for i in 0..k {
            for j in 0..k {
                w_mat.data[i * k + j] = winv[i * l + j];
            }
        }
        Ok((
            NystromApprox {
                indices: lambda,
                c: c_mat,
                winv: w_mat,
                selection_secs: sw.secs(),
            },
            trace,
        ))
    }
}

fn mirror_col(c32: &mut [f32], col: &[f64], t: usize, l_pad: usize) {
    for (i, &v) in col.iter().enumerate() {
        c32[i * l_pad + t] = v as f32;
    }
}

fn mirror_row(r32: &mut [f32], row: &[f64], t: usize, n_pad: usize) {
    let dst = &mut r32[t * n_pad..t * n_pad + row.len()];
    for (o, &v) in dst.iter_mut().zip(row) {
        *o = v as f32;
    }
}

/// Convenience: a `ColumnSampler` wrapper owning its accel context.
pub struct AccelOasisSampler {
    pub inner: PjrtOasis,
    accel: std::sync::Mutex<Accel>,
}

impl AccelOasisSampler {
    pub fn new(inner: PjrtOasis, accel: Accel) -> Self {
        AccelOasisSampler { inner, accel: std::sync::Mutex::new(accel) }
    }
}

impl ColumnSampler for AccelOasisSampler {
    fn name(&self) -> &'static str {
        "oASIS (PJRT)"
    }

    fn sample(&self, oracle: &dyn ColumnOracle) -> Result<NystromApprox> {
        let mut accel = self
            .accel
            .lock()
            .map_err(|_| anyhow!("accel mutex poisoned"))?;
        if oracle.n() == 0 {
            bail!("empty oracle");
        }
        self.inner.sample_with(&mut accel, oracle).map(|(a, _)| a)
    }
}
