//! Artifact-accelerated oASIS: the L3 hot path backed by the AOT-lowered
//! L2/L1 modules (Δ-scoring, Gaussian kernel columns) with zero-padding to
//! the fixed artifact shapes, and a native fallback when no artifact fits.
//!
//! The padding contract (tested in python/tests and here): C is padded to
//! (n_pad × l_pad) row-major f32 with zeros beyond (n, k), R to
//! (l_pad × n_pad); zero padding leaves Δ = d − colsum(C∘R) unchanged, so
//! one artifact serves every iteration k ≤ l_pad.
//!
//! Scoring is served one *session step* at a time: [`PjrtOasisSession`]
//! implements [`SamplerSession`], running the Δ-scoring artifact per
//! [`step`](SamplerSession::step) while maintaining the f64 state and its
//! padded f32 mirrors natively. [`PjrtOasis::sample_with`] is the one-shot
//! adapter.

use super::{Executor, Manifest};
use crate::linalg::Mat;
use crate::nystrom::{assembly, NystromApprox};
use crate::sampling::{
    run_to_completion, ColumnOracle, ColumnSampler, SamplerSession,
    SelectionTrace, StepOutcome, StopReason, StoppingRule,
};
use crate::util::{rng::Pcg64, timing::Stopwatch};
use crate::{anyhow, bail};
use crate::Result;
use std::path::Path;

/// Loaded manifest + executor, shared by accelerated ops.
pub struct Accel {
    pub manifest: Manifest,
    pub executor: Executor,
}

impl Accel {
    /// Load from an artifact directory.
    pub fn load(dir: &Path) -> Result<Accel> {
        let manifest = Manifest::load(dir)?;
        let executor = Executor::cpu()?;
        Ok(Accel { manifest, executor })
    }

    /// Load from `$OASIS_ARTIFACTS` / `./artifacts`; `None` if unavailable
    /// (missing artifacts are not an error — native fallback).
    pub fn try_default() -> Option<Accel> {
        Accel::load(&Manifest::default_dir()).ok()
    }

    /// Gaussian kernel columns through the `gaussian_columns` artifact:
    /// (n × m) block against (k × m) selected points. Falls back to an
    /// error if no artifact bucket fits; callers dispatch natively then.
    pub fn gaussian_columns(
        &mut self,
        z_blk: &[f64],
        n: usize,
        z_sel: &[f64],
        k: usize,
        m: usize,
        inv_sigma_sq: f64,
    ) -> Result<Vec<f64>> {
        let art = self
            .manifest
            .best_fit("gaussian_columns", n, &[("k", k), ("m", m)])
            .ok_or_else(|| anyhow!("no gaussian_columns artifact for n={n} k={k} m={m}"))?
            .clone();
        let (n_pad, k_pad, m_pad) = (
            art.dim("n").unwrap(),
            art.dim("k").unwrap(),
            art.dim("m").unwrap(),
        );
        self.executor.load(&art)?;
        // zero-pad inputs
        let mut zb = vec![0.0f32; n_pad * m_pad];
        for i in 0..n {
            for d in 0..m {
                zb[i * m_pad + d] = z_blk[i * m + d] as f32;
            }
        }
        let mut zs = vec![0.0f32; k_pad * m_pad];
        for i in 0..k {
            for d in 0..m {
                zs[i * m_pad + d] = z_sel[i * m + d] as f32;
            }
        }
        let gamma = [inv_sigma_sq as f32];
        let outs = self.executor.run_f32(
            &art.name,
            &[
                (&zb, &[n_pad as i64, m_pad as i64]),
                (&zs, &[k_pad as i64, m_pad as i64]),
                (&gamma, &[]),
            ],
        )?;
        let cols = &outs[0]; // (n_pad, k_pad)
        let mut out = vec![0.0f64; n * k];
        for i in 0..n {
            for j in 0..k {
                out[i * k + j] = cols[i * k_pad + j] as f64;
            }
        }
        Ok(out)
    }
}

/// oASIS with the Δ-scoring step served by the PJRT artifact. Maintains
/// the paper's R matrix natively (f64) plus f32 mirrors in the artifact's
/// padded layout; selection sequences match the native sampler to f32
/// precision (tested in rust/tests/runtime_pjrt.rs).
pub struct PjrtOasis {
    pub max_cols: usize,
    pub init_cols: usize,
    pub tol: f64,
    pub seed: u64,
}

impl PjrtOasis {
    pub fn new(max_cols: usize, init_cols: usize, tol: f64, seed: u64) -> Self {
        PjrtOasis { max_cols, init_cols, tol, seed }
    }

    /// Open an accelerated session: picks and compiles the Δ-scoring
    /// artifact bucket, seeds (same RNG stream / rejection rule as the
    /// native sampler), and mirrors the state into the padded layout.
    /// Capacity is fixed at the artifact's `l` bucket.
    pub fn session<'a>(
        &self,
        accel: &'a mut Accel,
        oracle: &'a dyn ColumnOracle,
    ) -> Result<PjrtOasisSession<'a>> {
        let sw = Stopwatch::start();
        let n = oracle.n();
        let l = self.max_cols.min(n);
        let art = accel
            .manifest
            .best_fit("delta_scores", n, &[("l", l)])
            .ok_or_else(|| anyhow!("no delta_scores artifact for n={n} l={l}"))?
            .clone();
        let n_pad = art.dim("n").unwrap();
        let l_pad = art.dim("l").unwrap();
        accel.executor.load(&art)?;

        let d = oracle.diag();
        let tol = crate::sampling::effective_tol(self.tol, &d);
        let d_abs_sum = d.iter().map(|x| x.abs()).sum();
        let mut d32 = vec![0.0f32; n_pad];
        for i in 0..n {
            d32[i] = d[i] as f32;
        }

        // native f64 state (C column-major, W⁻¹ strided, R row-major)
        let mut c: Vec<f64> = Vec::with_capacity(l * n);
        let mut winv = vec![0.0f64; l * l];
        let mut r = vec![0.0f64; l * n];
        // f32 mirrors in artifact layout
        let mut c32 = vec![0.0f32; n_pad * l_pad];
        let mut r32 = vec![0.0f32; l_pad * n_pad];

        // --- seed (same stream/rejection as the native sampler) ---
        let mut rng = Pcg64::new(self.seed);
        let k0 = self.init_cols.min(l);
        let lambda: Vec<usize>;
        loop {
            let cand = rng.sample_without_replacement(n, k0);
            c.clear();
            c.resize(k0 * n, 0.0);
            for (t, &j) in cand.iter().enumerate() {
                oracle.column_into(j, &mut c[t * n..(t + 1) * n]);
            }
            let mut w = Mat::zeros(k0, k0);
            for (ti, &i) in cand.iter().enumerate() {
                for tj in 0..k0 {
                    *w.at_mut(ti, tj) = c[tj * n + i];
                }
            }
            if let Some(inv) = crate::linalg::inverse(&w) {
                let cond = inv.max_abs() * w.max_abs();
                if cond.is_finite() && cond <= 1e12 {
                    for i in 0..k0 {
                        for j in 0..k0 {
                            winv[i * l + j] = inv.at(i, j);
                        }
                    }
                    lambda = cand;
                    break;
                }
            }
        }
        // R₀ = W₀⁻¹ C₀ᵀ
        let k = k0;
        for t in 0..k {
            for i in 0..n {
                let mut acc = 0.0;
                for u in 0..k {
                    acc += winv[t * l + u] * c[u * n + i];
                }
                r[t * n + i] = acc;
            }
        }
        // mirrors
        for t in 0..k {
            mirror_col(&mut c32, &c[t * n..(t + 1) * n], t, l_pad);
            mirror_row(&mut r32, &r[t * n..(t + 1) * n], t, n_pad);
        }

        let mut selected = vec![false; n];
        let mut trace = SelectionTrace::default();
        for &j in &lambda {
            selected[j] = true;
            trace.order.push(j);
            trace.cum_secs.push(sw.secs());
            trace.deltas.push(f64::NAN);
        }

        Ok(PjrtOasisSession {
            accel,
            oracle,
            art_name: art.name,
            n,
            n_pad,
            l_pad,
            capacity: l,
            tol,
            d32,
            d_abs_sum,
            c,
            winv,
            r,
            c32,
            r32,
            diff: vec![0.0f64; n],
            resid_sum: None,
            selected,
            trace,
            exhausted: None,
            busy_secs: sw.secs(),
        })
    }

    /// Run selection using `accel` for scoring (one-shot adapter over the
    /// session + a column-budget rule).
    pub fn sample_with(
        &self,
        accel: &mut Accel,
        oracle: &dyn ColumnOracle,
    ) -> Result<(NystromApprox, SelectionTrace)> {
        let mut session = self.session(accel, oracle)?;
        run_to_completion(&mut session, &StoppingRule::budget(self.max_cols))?;
        let trace = session.trace().clone();
        let approx = session.snapshot()?;
        Ok((approx, trace))
    }
}

/// A paused PJRT-scored oASIS run (see [`PjrtOasis::session`]).
pub struct PjrtOasisSession<'a> {
    accel: &'a mut Accel,
    oracle: &'a dyn ColumnOracle,
    art_name: String,
    n: usize,
    n_pad: usize,
    l_pad: usize,
    /// fixed capacity: the state buffers are allocated at the constructor
    /// budget (bounded by the artifact's `l` bucket).
    capacity: usize,
    tol: f64,
    d32: Vec<f32>,
    d_abs_sum: f64,
    /// C column-major (f64 source of truth).
    c: Vec<f64>,
    /// W⁻¹, stride `capacity`.
    winv: Vec<f64>,
    /// R row-major, stride n.
    r: Vec<f64>,
    /// padded f32 mirrors in artifact layout.
    c32: Vec<f32>,
    r32: Vec<f32>,
    diff: Vec<f64>,
    /// Σ|Δ| over unselected candidates from the latest artifact scoring.
    resid_sum: Option<f64>,
    selected: Vec<bool>,
    trace: SelectionTrace,
    exhausted: Option<StopReason>,
    busy_secs: f64,
}

impl SamplerSession for PjrtOasisSession<'_> {
    fn name(&self) -> &'static str {
        "oASIS (PJRT)"
    }

    fn n(&self) -> usize {
        self.n
    }

    fn indices(&self) -> &[usize] {
        &self.trace.order
    }

    fn trace(&self) -> &SelectionTrace {
        &self.trace
    }

    fn selection_secs(&self) -> f64 {
        self.busy_secs
    }

    /// Residual trace ratio from the latest f32 Δ sweep (`None` before
    /// the first step).
    fn error_estimate(&self) -> Option<f64> {
        let sum = self.resid_sum?;
        if self.d_abs_sum <= 0.0 {
            return Some(0.0);
        }
        Some(sum / self.d_abs_sum)
    }

    fn step(&mut self) -> Result<StepOutcome> {
        if let Some(reason) = self.exhausted {
            return Ok(StepOutcome::Exhausted(reason));
        }
        let sw = Stopwatch::start();
        let n = self.n;
        let l = self.capacity;
        let k = self.trace.order.len();
        if k >= l {
            // fixed-shape artifact state cannot grow past its bucket
            self.exhausted = Some(StopReason::Exhausted);
            self.busy_secs += sw.secs();
            return Ok(StepOutcome::Exhausted(StopReason::Exhausted));
        }
        // Δ via the PJRT artifact
        let outs = self.accel.executor.run_f32(
            &self.art_name,
            &[
                (&self.c32, &[self.n_pad as i64, self.l_pad as i64]),
                (&self.r32, &[self.l_pad as i64, self.n_pad as i64]),
                (&self.d32, &[self.n_pad as i64]),
            ],
        )?;
        let delta32 = &outs[0];
        let mut best = usize::MAX;
        let mut best_abs = -1.0f64;
        let mut sum_abs = 0.0f64;
        for i in 0..n {
            if self.selected[i] {
                continue;
            }
            let a = (delta32[i] as f64).abs();
            sum_abs += a;
            if a > best_abs {
                best_abs = a;
                best = i;
            }
        }
        self.resid_sum = Some(sum_abs);
        if best == usize::MAX {
            self.exhausted = Some(StopReason::Exhausted);
            self.busy_secs += sw.secs();
            return Ok(StepOutcome::Exhausted(StopReason::Exhausted));
        }
        if best_abs < self.tol {
            self.exhausted = Some(StopReason::ScoreBelowTol);
            self.busy_secs += sw.secs();
            return Ok(StepOutcome::Exhausted(StopReason::ScoreBelowTol));
        }
        let s = 1.0 / delta32[best] as f64;
        let mut col = vec![0.0f64; n];
        self.oracle.column_into(best, &mut col);
        // q = W⁻¹ b
        let mut q = vec![0.0f64; k];
        for (t, qt) in q.iter_mut().enumerate() {
            let mut acc = 0.0;
            for u in 0..k {
                acc += self.winv[t * l + u] * self.c[u * n + best];
            }
            *qt = acc;
        }
        // diff = Cq − c_new
        for i in 0..n {
            let mut acc = 0.0;
            for (t, &qt) in q.iter().enumerate() {
                acc += qt * self.c[t * n + i];
            }
            self.diff[i] = acc - col[i];
        }
        // Eq. 5 (W⁻¹)
        for i in 0..k {
            for j in 0..k {
                self.winv[i * l + j] += s * q[i] * q[j];
            }
            self.winv[i * l + k] = -s * q[i];
            self.winv[k * l + i] = -s * q[i];
        }
        self.winv[k * l + k] = s;
        // Eq. 6 (R) + mirrors
        for t in 0..k {
            let f = s * q[t];
            let row = &mut self.r[t * n..(t + 1) * n];
            for (o, &dv) in row.iter_mut().zip(&self.diff) {
                *o += f * dv;
            }
            mirror_row(&mut self.r32, row, t, self.n_pad);
        }
        for i in 0..n {
            self.r[k * n + i] = -s * self.diff[i];
        }
        mirror_row(&mut self.r32, &self.r[k * n..(k + 1) * n], k, self.n_pad);
        self.c.extend_from_slice(&col);
        mirror_col(&mut self.c32, &col, k, self.l_pad);

        self.selected[best] = true;
        self.trace.order.push(best);
        self.trace.cum_secs.push(self.busy_secs + sw.secs());
        self.trace.deltas.push(best_abs);
        self.busy_secs += sw.secs();
        Ok(StepOutcome::Selected { index: best, score: best_abs })
    }

    fn snapshot(&self) -> Result<NystromApprox> {
        Ok(assembly::approx_from_colmajor(
            self.trace.order.clone(),
            self.n,
            &self.c,
            &self.winv,
            self.capacity,
            self.busy_secs,
        ))
    }
}

fn mirror_col(c32: &mut [f32], col: &[f64], t: usize, l_pad: usize) {
    for (i, &v) in col.iter().enumerate() {
        c32[i * l_pad + t] = v as f32;
    }
}

fn mirror_row(r32: &mut [f32], row: &[f64], t: usize, n_pad: usize) {
    let dst = &mut r32[t * n_pad..t * n_pad + row.len()];
    for (o, &v) in dst.iter_mut().zip(row) {
        *o = v as f32;
    }
}

/// Convenience: a `ColumnSampler` wrapper owning its accel context.
pub struct AccelOasisSampler {
    pub inner: PjrtOasis,
    accel: std::sync::Mutex<Accel>,
}

impl AccelOasisSampler {
    pub fn new(inner: PjrtOasis, accel: Accel) -> Self {
        AccelOasisSampler { inner, accel: std::sync::Mutex::new(accel) }
    }
}

impl ColumnSampler for AccelOasisSampler {
    fn name(&self) -> &'static str {
        "oASIS (PJRT)"
    }

    fn sample(&self, oracle: &dyn ColumnOracle) -> Result<NystromApprox> {
        let mut accel = self
            .accel
            .lock()
            .map_err(|_| anyhow!("accel mutex poisoned"))?;
        if oracle.n() == 0 {
            bail!("empty oracle");
        }
        self.inner.sample_with(&mut accel, oracle).map(|(a, _)| a)
    }
}
