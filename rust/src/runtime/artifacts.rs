//! Artifact manifest: which fixed-shape AOT modules exist and what they
//! compute. Mirrors the JSON written by `python/compile/aot.py`.

use crate::anyhow;
use crate::error::Context;
use crate::util::json::Json;
use crate::Result;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One input of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT-lowered module.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    /// absolute path to the .hlo.txt file
    pub path: PathBuf,
    /// which L2 op this lowers (e.g. "delta_scores")
    pub op: String,
    /// symbolic dims (n, l, k, m, ...)
    pub dims: BTreeMap<String, usize>,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<String>,
}

impl Artifact {
    pub fn dim(&self, name: &str) -> Option<usize> {
        self.dims.get(name).copied()
    }
}

/// The parsed artifact registry.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: Vec<Artifact>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON (paths resolved relative to `dir`).
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let version = j
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing version"))?;
        if version != 1 {
            return Err(anyhow!("unsupported manifest version {version}"));
        }
        let arts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?;
        let mut out = Vec::with_capacity(arts.len());
        for a in arts {
            let name = field_str(a, "name")?;
            let file = field_str(a, "file")?;
            let op = field_str(a, "op")?;
            let mut dims = BTreeMap::new();
            if let Some(dj) = a.get("dims").and_then(Json::as_obj) {
                for (k, v) in dj {
                    dims.insert(
                        k.clone(),
                        v.as_usize().ok_or_else(|| anyhow!("bad dim {k}"))?,
                    );
                }
            }
            let mut inputs = Vec::new();
            for inp in a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact {name}: missing inputs"))?
            {
                inputs.push(InputSpec {
                    name: field_str(inp, "name")?,
                    shape: inp
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("bad shape"))?
                        .iter()
                        .map(|x| x.as_usize().unwrap_or(0))
                        .collect(),
                    dtype: field_str(inp, "dtype")?,
                });
            }
            let outputs = a
                .get("outputs")
                .and_then(Json::as_arr)
                .map(|v| {
                    v.iter()
                        .filter_map(|x| x.as_str().map(String::from))
                        .collect()
                })
                .unwrap_or_default();
            out.push(Artifact {
                name,
                path: dir.join(&file),
                op,
                dims,
                inputs,
                outputs,
            });
        }
        Ok(Manifest { artifacts: out })
    }

    /// All artifacts lowering a given op.
    pub fn for_op(&self, op: &str) -> Vec<&Artifact> {
        self.artifacts.iter().filter(|a| a.op == op).collect()
    }

    /// Smallest artifact of `op` whose `n` bucket fits `n` (and whose other
    /// dims satisfy the given minimums).
    pub fn best_fit(&self, op: &str, n: usize, mins: &[(&str, usize)]) -> Option<&Artifact> {
        self.for_op(op)
            .into_iter()
            .filter(|a| a.dim("n").map(|an| an >= n).unwrap_or(false))
            .filter(|a| {
                mins.iter().all(|(k, v)| a.dim(k).map(|d| d >= *v).unwrap_or(false))
            })
            .min_by_key(|a| a.dim("n").unwrap())
    }

    /// Default artifact directory: `$OASIS_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("OASIS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

fn field_str(j: &Json, key: &str) -> Result<String> {
    j.get(key)
        .and_then(Json::as_str)
        .map(String::from)
        .ok_or_else(|| anyhow!("missing field {key}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"version":1,"artifacts":[
      {"name":"delta_n1024_l512","file":"delta_n1024_l512.hlo.txt",
       "op":"delta_scores","dims":{"n":1024,"l":512},
       "inputs":[{"name":"c","shape":[1024,512],"dtype":"float32"},
                 {"name":"r","shape":[512,1024],"dtype":"float32"},
                 {"name":"d","shape":[1024],"dtype":"float32"}],
       "outputs":["delta"]},
      {"name":"delta_n4096_l512","file":"delta_n4096_l512.hlo.txt",
       "op":"delta_scores","dims":{"n":4096,"l":512},
       "inputs":[{"name":"c","shape":[4096,512],"dtype":"float32"}],
       "outputs":["delta"]}]}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = &m.artifacts[0];
        assert_eq!(a.dim("n"), Some(1024));
        assert_eq!(a.inputs[1].shape, vec![512, 1024]);
        assert_eq!(a.path, Path::new("/tmp/a/delta_n1024_l512.hlo.txt"));
    }

    #[test]
    fn best_fit_picks_smallest_bucket() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        assert_eq!(
            m.best_fit("delta_scores", 1000, &[("l", 100)]).unwrap().dim("n"),
            Some(1024)
        );
        assert_eq!(
            m.best_fit("delta_scores", 2000, &[]).unwrap().dim("n"),
            Some(4096)
        );
        assert!(m.best_fit("delta_scores", 10_000, &[]).is_none());
        assert!(m.best_fit("delta_scores", 100, &[("l", 1000)]).is_none());
    }

    #[test]
    fn rejects_bad_version() {
        let bad = r#"{"version":2,"artifacts":[]}"#;
        assert!(Manifest::parse(bad, Path::new(".")).is_err());
    }
}
