//! The general column-subset-selection problem (paper Eq. 7 / §IV-A3):
//! `min_{|Λ|=L} ‖Z − P_Λ Z‖_F` over a data matrix Z, solved greedily by
//! running oASIS on the Gram matrix G = ZᵀZ. When |Λ| reaches rank(Z),
//! the projection is exact — the guarantee SEED builds on.

use crate::data::Dataset;
use crate::kernels::Linear;
use crate::linalg::{thin_qr, Mat};
use crate::sampling::{oasis::Oasis, ColumnSampler, ImplicitOracle};
use crate::Result;

/// Select `l` representative points from the dataset by oASIS on the Gram
/// matrix (never formed explicitly). Returns Λ in selection order.
pub fn select_css(ds: &Dataset, l: usize, seed: u64) -> Result<Vec<usize>> {
    let kern = Linear;
    let oracle = ImplicitOracle::new(ds, &kern);
    let approx = Oasis::new(l, 1, 1e-12, seed).sample(&oracle)?;
    Ok(approx.indices)
}

/// The Eq. 7 objective: ‖Z − P_Λ Z‖_F / ‖Z‖_F where P_Λ projects onto the
/// span of the selected points (columns of the paper's m×n Z — rows of our
/// point-major Dataset).
pub fn css_projection_error(ds: &Dataset, lambda: &[usize]) -> f64 {
    let m = ds.dim();
    let n = ds.n();
    // Z_Λ as an m×|Λ| matrix (points are columns)
    let mut zl = Mat::zeros(m, lambda.len());
    for (c, &j) in lambda.iter().enumerate() {
        for d in 0..m {
            *zl.at_mut(d, c) = ds.point(j)[d];
        }
    }
    let (q, _r) = thin_qr(&zl); // orthonormal basis of span(Z_Λ)
    let mut num = 0.0;
    let mut den = 0.0;
    let mut proj = vec![0.0; q.cols];
    for i in 0..n {
        let z = ds.point(i);
        // coefficients Qᵀz
        for (c, p) in proj.iter_mut().enumerate() {
            let mut acc = 0.0;
            for d in 0..m {
                acc += q.at(d, c) * z[d];
            }
            *p = acc;
        }
        for d in 0..m {
            let mut r = z[d];
            for (c, &p) in proj.iter().enumerate() {
                r -= q.at(d, c) * p;
            }
            num += r * r;
            den += z[d] * z[d];
        }
    }
    if den == 0.0 {
        0.0
    } else {
        (num / den).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{gauss_2d_plus_3d, mnist_like};
    use crate::util::rng::Pcg64;

    /// §IV-A3: for Z of rank m, oASIS selects |Λ| = m with exact projection.
    #[test]
    fn exact_projection_at_rank() {
        let ds = gauss_2d_plus_3d(50, 50, 7); // rank-3 point set in R³
        let lambda = select_css(&ds, 5, 3).unwrap();
        assert!(lambda.len() <= 4, "selected {} for rank 3", lambda.len());
        let err = css_projection_error(&ds, &lambda);
        assert!(err < 1e-8, "projection error {err}");
    }

    #[test]
    fn css_error_decreases_with_budget() {
        let ds = mnist_like(120, 32, 5);
        let mut prev = f64::INFINITY;
        for l in [2usize, 5, 10, 20] {
            let lambda = select_css(&ds, l, 1).unwrap();
            let err = css_projection_error(&ds, &lambda);
            assert!(err <= prev + 1e-9, "error rose at l={l}: {prev} → {err}");
            prev = err;
        }
        assert!(prev < 0.7, "final css error {prev}");
    }

    #[test]
    fn oasis_css_beats_random_selection() {
        let ds = mnist_like(150, 40, 9);
        let l = 12;
        let lam_oasis = select_css(&ds, l, 2).unwrap();
        let e_oasis = css_projection_error(&ds, &lam_oasis);
        let mut e_rand = 0.0;
        let mut rng = Pcg64::new(11);
        for _ in 0..5 {
            let lam: Vec<usize> = rng.sample_without_replacement(ds.n(), l);
            e_rand += css_projection_error(&ds, &lam);
        }
        e_rand /= 5.0;
        assert!(
            e_oasis <= e_rand + 1e-12,
            "oasis {e_oasis} vs random {e_rand}"
        );
    }

    #[test]
    fn empty_lambda_full_error() {
        let ds = mnist_like(30, 8, 2);
        let err = css_projection_error(&ds, &[]);
        assert!((err - 1.0).abs() < 1e-12);
    }
}
