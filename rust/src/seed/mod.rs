//! SEED — Sparse Self-Expressive Decomposition (paper §II-E, [30]).
//!
//! The paper's companion application of oASIS: (1) select a dictionary of
//! representative *data points* with oASIS on the Gram matrix, then
//! (2) represent every point as a sparse combination of dictionary points
//! with Orthogonal Matching Pursuit. The sparse codes drive clustering,
//! denoising and classification; §IV-A3's guarantee (exact recovery of Z
//! when |Λ| reaches rank(Z)) is what makes the oASIS-selected dictionary
//! sufficient.

pub mod cluster;
pub mod css;
pub mod decompose;
pub mod omp;

pub use cluster::{permutation_accuracy, spectral_cluster};
pub use css::{css_projection_error, select_css};
pub use decompose::{Seed, SeedConfig};
pub use omp::{omp, SparseCode};
