//! The SEED decomposition: oASIS dictionary selection + OMP sparse coding.

use super::css::select_css;
use super::omp::{omp, SparseCode};
use crate::data::Dataset;
use crate::linalg::Mat;
use crate::util::parallel;
use crate::Result;

/// Configuration for a SEED run.
#[derive(Clone, Debug)]
pub struct SeedConfig {
    /// dictionary size L (number of selected data points).
    pub dict_size: usize,
    /// per-point sparsity budget for OMP.
    pub sparsity: usize,
    /// OMP early-stop tolerance on the squared residual.
    pub tol_sq: f64,
    pub seed: u64,
}

impl Default for SeedConfig {
    fn default() -> Self {
        SeedConfig { dict_size: 50, sparsity: 5, tol_sq: 1e-12, seed: 7 }
    }
}

/// A computed SEED decomposition: `Z ≈ Z_Λ X` with column-sparse X.
#[derive(Clone, Debug)]
pub struct Seed {
    /// dictionary: indices of the selected data points (Λ).
    pub dictionary: Vec<usize>,
    /// sparse code of each data point over the dictionary.
    pub codes: Vec<SparseCode>,
    /// ‖Z − Z_Λ X‖_F / ‖Z‖_F
    pub relative_error: f64,
}

impl Seed {
    /// Run SEED on a dataset.
    pub fn decompose(ds: &Dataset, cfg: &SeedConfig) -> Result<Seed> {
        let dictionary = select_css(ds, cfg.dict_size, cfg.seed)?;
        let m = ds.dim();
        // dictionary matrix m×L (points as columns)
        let mut dict = Mat::zeros(m, dictionary.len());
        for (c, &j) in dictionary.iter().enumerate() {
            for d in 0..m {
                *dict.at_mut(d, c) = ds.point(j)[d];
            }
        }
        let n = ds.n();
        let codes: Vec<SparseCode> = parallel::map_ranges(
            n,
            parallel::default_threads(),
            |range| {
                range
                    .map(|i| omp(&dict, ds.point(i), cfg.sparsity, cfg.tol_sq))
                    .collect::<Vec<_>>()
            },
        )
        .into_iter()
        .flatten()
        .collect();
        let num: f64 = codes.iter().map(|c| c.residual_sq).sum();
        let den: f64 = (0..n)
            .map(|i| ds.point(i).iter().map(|x| x * x).sum::<f64>())
            .sum();
        Ok(Seed {
            dictionary,
            codes,
            relative_error: if den == 0.0 { 0.0 } else { (num / den).sqrt() },
        })
    }

    /// Symmetric affinity matrix `|X|ᵀ|X|`-style for clustering: points
    /// sharing dictionary atoms (with similar signs/weights) are similar.
    /// Returns a dense n×n affinity (intended for SEED-scale demos).
    pub fn affinity(&self) -> Mat {
        let n = self.codes.len();
        let l = self.dictionary.len();
        // dense code matrix n×L of |coefficients|, row-normalized
        let mut x = Mat::zeros(n, l);
        for (i, code) in self.codes.iter().enumerate() {
            let nrm: f64 = code
                .entries
                .iter()
                .map(|(_, v)| v * v)
                .sum::<f64>()
                .sqrt()
                .max(1e-300);
            for &(j, v) in &code.entries {
                *x.at_mut(i, j) = v.abs() / nrm;
            }
        }
        let mut a = x.matmul(&x.transpose());
        // zero the diagonal (self-affinity is uninformative)
        for i in 0..n {
            *a.at_mut(i, i) = 0.0;
        }
        a.symmetrize();
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{gaussian_clusters, mnist_like};

    #[test]
    fn decomposition_error_small_on_low_rank() {
        let ds = mnist_like(200, 32, 3);
        let seed = Seed::decompose(
            &ds,
            &SeedConfig { dict_size: 40, sparsity: 8, ..Default::default() },
        )
        .unwrap();
        assert_eq!(seed.codes.len(), 200);
        assert!(
            seed.relative_error < 0.25,
            "SEED error {}",
            seed.relative_error
        );
        // all codes respect the sparsity budget
        assert!(seed.codes.iter().all(|c| c.entries.len() <= 8));
    }

    #[test]
    fn affinity_higher_within_cluster() {
        let ds = gaussian_clusters(90, 6, 3, 0.1, 5);
        let seed = Seed::decompose(
            &ds,
            &SeedConfig { dict_size: 12, sparsity: 3, ..Default::default() },
        )
        .unwrap();
        let a = seed.affinity();
        // average within-cluster vs across-cluster affinity (labels = i%3)
        let (mut win, mut wn, mut across, mut an) = (0.0, 0, 0.0, 0);
        for i in 0..90 {
            for j in 0..90 {
                if i == j {
                    continue;
                }
                if i % 3 == j % 3 {
                    win += a.at(i, j);
                    wn += 1;
                } else {
                    across += a.at(i, j);
                    an += 1;
                }
            }
        }
        let (win, across) = (win / wn as f64, across / an as f64);
        assert!(
            win > 2.0 * across,
            "within {win} not ≫ across {across}"
        );
    }

    #[test]
    fn dictionary_indices_valid_and_distinct() {
        let ds = mnist_like(80, 16, 1);
        let seed = Seed::decompose(&ds, &SeedConfig::default()).unwrap();
        let set: std::collections::HashSet<_> = seed.dictionary.iter().collect();
        assert_eq!(set.len(), seed.dictionary.len());
        assert!(seed.dictionary.iter().all(|&i| i < 80));
    }
}
