//! Spectral clustering on a SEED affinity matrix — the clustering
//! application the paper cites for SEED (§II-E) and the future-work
//! direction (spectral clustering) of §VI.

use crate::data::Dataset;
use crate::linalg::{sym_eig, Mat};
use crate::sampling::kmeans::KMeans;

/// Normalized spectral clustering (Ng–Jordan–Weiss style):
/// symmetric-normalize the affinity, embed into the top-k eigenvectors,
/// row-normalize, and run k-means. Returns cluster labels.
pub fn spectral_cluster(affinity: &Mat, k: usize, seed: u64) -> Vec<usize> {
    assert_eq!(affinity.rows, affinity.cols);
    let n = affinity.rows;
    let k = k.min(n).max(1);
    // M = D^{-1/2} A D^{-1/2}
    let mut m = affinity.clone();
    let deg: Vec<f64> = (0..n)
        .map(|i| m.row(i).iter().sum::<f64>().max(1e-12))
        .collect();
    let inv_sqrt: Vec<f64> = deg.iter().map(|&d| 1.0 / d.sqrt()).collect();
    for i in 0..n {
        for j in 0..n {
            *m.at_mut(i, j) *= inv_sqrt[i] * inv_sqrt[j];
        }
    }
    let eig = sym_eig(&m);
    // top-k eigenvectors as embedding rows, row-normalized
    let mut emb = Dataset::zeros(n, k);
    for i in 0..n {
        let mut nrm = 0.0;
        for c in 0..k {
            let v = eig.vecs.at(i, c);
            nrm += v * v;
        }
        let nrm = nrm.sqrt().max(1e-12);
        let p = emb.point_mut(i);
        for (c, pv) in p.iter_mut().enumerate() {
            *pv = eig.vecs.at(i, c) / nrm;
        }
    }
    let (_, labels, _) = KMeans::new(k, seed).fit(&emb);
    labels
}

/// Clustering accuracy against ground truth up to label permutation
/// (exhaustive over k! permutations; intended for k ≤ 6 in tests).
pub fn permutation_accuracy(labels: &[usize], truth: &[usize], k: usize) -> f64 {
    assert_eq!(labels.len(), truth.len());
    fn permutations(k: usize) -> Vec<Vec<usize>> {
        if k == 1 {
            return vec![vec![0]];
        }
        let mut out = Vec::new();
        for p in permutations(k - 1) {
            for pos in 0..=p.len() {
                let mut q = p.clone();
                q.insert(pos, k - 1);
                out.push(q);
            }
        }
        out
    }
    let n = labels.len() as f64;
    let mut best = 0.0;
    for perm in permutations(k) {
        let correct = labels
            .iter()
            .zip(truth)
            .filter(|(&l, &t)| perm.get(l).copied() == Some(t))
            .count();
        best = f64::max(best, correct as f64 / n);
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::gaussian_clusters;
    use crate::seed::{Seed, SeedConfig};

    #[test]
    fn clusters_well_separated_data() {
        let ds = gaussian_clusters(120, 5, 3, 0.08, 4);
        let truth: Vec<usize> = (0..120).map(|i| i % 3).collect();
        let seed = Seed::decompose(
            &ds,
            &SeedConfig { dict_size: 15, sparsity: 3, ..Default::default() },
        )
        .unwrap();
        let labels = spectral_cluster(&seed.affinity(), 3, 9);
        let acc = permutation_accuracy(&labels, &truth, 3);
        assert!(acc > 0.9, "clustering accuracy {acc}");
    }

    #[test]
    fn permutation_accuracy_handles_relabeling() {
        let truth = vec![0, 0, 1, 1, 2, 2];
        let labels = vec![2, 2, 0, 0, 1, 1]; // perfect up to permutation
        assert_eq!(permutation_accuracy(&labels, &truth, 3), 1.0);
        let noisy = vec![2, 1, 0, 0, 1, 1];
        assert!((permutation_accuracy(&noisy, &truth, 3) - 5.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn single_cluster_trivial() {
        let a = Mat::from_fn(5, 5, |i, j| if i == j { 0.0 } else { 1.0 });
        let labels = spectral_cluster(&a, 1, 3);
        assert!(labels.iter().all(|&l| l == 0));
    }
}
