//! Orthogonal Matching Pursuit ([31], [32]) — the sparse-coding step of
//! SEED: greedily select dictionary atoms by residual correlation and
//! re-fit least squares over the active set.

use crate::linalg::{lu_solve, Mat};

/// A sparse code: (atom index, coefficient) pairs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseCode {
    pub entries: Vec<(usize, f64)>,
    /// squared norm of the final residual
    pub residual_sq: f64,
}

impl SparseCode {
    /// Dense coefficient vector of length `dict_size`.
    pub fn to_dense(&self, dict_size: usize) -> Vec<f64> {
        let mut x = vec![0.0; dict_size];
        for &(j, v) in &self.entries {
            x[j] = v;
        }
        x
    }
}

/// Solve `min ‖y − D x‖₂  s.t. ‖x‖₀ ≤ sparsity` greedily.
///
/// `dict` is m×k with unit-normalized-ish columns (atoms); `y` is length m.
/// Stops early when the residual norm² drops below `tol_sq`.
pub fn omp(dict: &Mat, y: &[f64], sparsity: usize, tol_sq: f64) -> SparseCode {
    let (m, k) = (dict.rows, dict.cols);
    assert_eq!(y.len(), m);
    let t = sparsity.min(k);
    let mut residual = y.to_vec();
    let mut active: Vec<usize> = Vec::with_capacity(t);
    let mut coef: Vec<f64> = Vec::new();
    for _ in 0..t {
        let r2: f64 = residual.iter().map(|x| x * x).sum();
        if r2 <= tol_sq {
            break;
        }
        // atom most correlated with the residual (normalized)
        let mut best = usize::MAX;
        let mut best_score = 0.0;
        for j in 0..k {
            if active.contains(&j) {
                continue;
            }
            let mut dot = 0.0;
            let mut nrm = 0.0;
            for i in 0..m {
                let dij = dict.at(i, j);
                dot += dij * residual[i];
                nrm += dij * dij;
            }
            if nrm <= 1e-300 {
                continue;
            }
            let score = dot * dot / nrm;
            if score > best_score {
                best_score = score;
                best = j;
            }
        }
        if best == usize::MAX || best_score <= 1e-300 {
            break;
        }
        active.push(best);
        // least squares over the active set: solve (DᵀD) x = Dᵀ y
        let s = active.len();
        let mut gram = Mat::zeros(s, s);
        let mut rhs = vec![0.0; s];
        for (a, &ja) in active.iter().enumerate() {
            for (b, &jb) in active.iter().enumerate() {
                let mut acc = 0.0;
                for i in 0..m {
                    acc += dict.at(i, ja) * dict.at(i, jb);
                }
                *gram.at_mut(a, b) = acc;
            }
            let mut acc = 0.0;
            for i in 0..m {
                acc += dict.at(i, ja) * y[i];
            }
            rhs[a] = acc;
        }
        // ridge jitter for safety on near-duplicate atoms
        for a in 0..s {
            *gram.at_mut(a, a) += 1e-12;
        }
        coef = lu_solve(&gram, &rhs).unwrap_or_else(|| vec![0.0; s]);
        // residual = y − D_active coef
        residual.copy_from_slice(y);
        for (a, &ja) in active.iter().enumerate() {
            let ca = coef[a];
            for i in 0..m {
                residual[i] -= ca * dict.at(i, ja);
            }
        }
    }
    SparseCode {
        residual_sq: residual.iter().map(|x| x * x).sum(),
        entries: active.into_iter().zip(coef).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_dict(m: usize, k: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut d = Mat::zeros(m, k);
        rng.fill_normal(&mut d.data);
        // normalize columns
        for j in 0..k {
            let nrm: f64 = (0..m).map(|i| d.at(i, j).powi(2)).sum::<f64>().sqrt();
            for i in 0..m {
                *d.at_mut(i, j) /= nrm;
            }
        }
        d
    }

    #[test]
    fn recovers_exact_sparse_combination() {
        let d = random_dict(20, 40, 1);
        // y = 2·atom3 − 1.5·atom17
        let mut y = vec![0.0; 20];
        for i in 0..20 {
            y[i] = 2.0 * d.at(i, 3) - 1.5 * d.at(i, 17);
        }
        let code = omp(&d, &y, 2, 1e-20);
        assert!(code.residual_sq < 1e-16, "residual {}", code.residual_sq);
        let dense = code.to_dense(40);
        assert!((dense[3] - 2.0).abs() < 1e-8);
        assert!((dense[17] + 1.5).abs() < 1e-8);
        for (j, &v) in dense.iter().enumerate() {
            if j != 3 && j != 17 {
                assert!(v.abs() < 1e-8, "spurious coefficient at {j}: {v}");
            }
        }
    }

    #[test]
    fn respects_sparsity_budget() {
        let d = random_dict(15, 30, 2);
        let mut rng = Pcg64::new(3);
        let mut y = vec![0.0; 15];
        rng.fill_normal(&mut y);
        let code = omp(&d, &y, 4, 0.0);
        assert!(code.entries.len() <= 4);
        // residual decreases monotonically with budget
        let r1 = omp(&d, &y, 1, 0.0).residual_sq;
        let r2 = omp(&d, &y, 2, 0.0).residual_sq;
        let r4 = code.residual_sq;
        assert!(r2 <= r1 + 1e-12);
        assert!(r4 <= r2 + 1e-12);
    }

    #[test]
    fn zero_signal_gives_empty_code() {
        let d = random_dict(10, 12, 4);
        let code = omp(&d, &vec![0.0; 10], 3, 1e-12);
        assert!(code.entries.is_empty());
        assert_eq!(code.residual_sq, 0.0);
    }

    #[test]
    fn early_stop_on_tolerance() {
        let d = random_dict(20, 40, 5);
        let mut y = vec![0.0; 20];
        for i in 0..20 {
            y[i] = d.at(i, 7);
        }
        // tolerance loose enough that 1 atom suffices
        let code = omp(&d, &y, 10, 1e-10);
        assert_eq!(code.entries.len(), 1);
        assert_eq!(code.entries[0].0, 7);
    }
}
