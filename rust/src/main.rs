//! `oasis` — CLI for the oASIS kernel-matrix approximation library.
//!
//! Subcommands:
//!   approximate  run one sampler on one dataset, report error + runtime
//!   parallel     run the distributed oASIS-P coordinator
//!   serve        host concurrent resumable sessions over HTTP/JSON
//!   info         show the artifact manifest and PJRT platform
//!
//! Examples:
//!   oasis approximate --dataset two-moons --n 2000 --cols 450 --method oasis
//!   oasis parallel --dataset two-moons --n 100000 --cols 500 --workers 8
//!   oasis serve --port 7437
//!   oasis info

use oasis::coordinator::{run_oasis_p, OasisPConfig};
use oasis::data::{generators, Dataset};
use oasis::kernels::{Gaussian, Kernel, Linear};
use oasis::nystrom::{relative_frobenius_error, sampled_relative_error, NystromApprox};
use oasis::runtime::{Accel, Manifest};
use oasis::sampling::{
    farahat::Farahat, kmeans::KMeansNystrom, leverage::LeverageScores,
    oasis::Oasis, run_to_completion, uniform::Uniform, ColumnSampler,
    ImplicitOracle, SamplerSession, StopReason, StoppingCriterion, StoppingRule,
};
use oasis::util::args::Args;
use oasis::util::json::Json;
use oasis::util::timing::fmt_secs;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match cmd {
        "approximate" => cmd_approximate(&args),
        "parallel" => cmd_parallel(&args),
        "seed" => cmd_seed(&args),
        "serve" => cmd_serve(&args),
        "info" => cmd_info(),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "oasis — adaptive column sampling for kernel matrix approximation\n\
         \n\
         USAGE: oasis <approximate|parallel|serve|info> [options]\n\
         \n\
         approximate options:\n\
           --dataset   two-moons|abalone|borg|mnist|salinas|lightfield (default two-moons)\n\
           --n         dataset size (default 2000)\n\
           --cols      columns to sample ℓ (default 450)\n\
           --method    oasis|random|leverage|farahat|kmeans (default oasis)\n\
           --kernel    gaussian|linear (default gaussian)\n\
           --sigma-frac  σ as fraction of max pairwise distance (default 0.05)\n\
           --error     full|sampled (default full for n ≤ 8000)\n\
           --seed      RNG seed (default 7)\n\
           --accel     use the PJRT artifact path for oASIS scoring\n\
           --target-err  stop once the estimated relative error reaches\n\
                         this (oasis/farahat; may stop before --cols)\n\
           --deadline-ms stop selection after this many milliseconds\n\
                         (oasis/farahat)\n\
           --json      structured one-line JSON output (method, k,\n\
                       error, secs, stop)\n\
         \n\
         parallel options:\n\
           --dataset/--n/--cols/--sigma-frac/--seed as above\n\
           --workers   node count p (default 8)\n\
           --tol       stopping tolerance (default 1e-12)\n\
         \n\
         seed options (SEED decomposition, §II-E):\n\
           --dataset/--n/--seed as above\n\
           --dict      dictionary size L (default 50)\n\
           --sparsity  per-point OMP budget (default 5)\n\
           --clusters  if set, spectral-cluster the codes into this many groups\n\
         \n\
         serve options (HTTP/JSON session server; protocol reference in\n\
         the oasis::server module docs):\n\
           --host      bind address (default 127.0.0.1)\n\
           --port      TCP port; 0 picks an ephemeral port, printed on\n\
                       the \"listening\" line (default 7437)\n"
    );
}

fn make_dataset(args: &Args) -> Dataset {
    let name = args.get_or("dataset", "two-moons");
    let n = args.usize_or("n", 2000);
    // XOR so dataset and sampler RNG streams differ for the same --seed
    // (the server passes seeds raw; see generators::by_name)
    let seed = args.u64_or("seed", 7) ^ 0xDA7A;
    match generators::by_name(&name, n, 0, 0.05, seed) {
        Some(ds) => ds,
        None => {
            eprintln!("unknown dataset '{name}'");
            std::process::exit(2);
        }
    }
}

/// Build the stopping rule from the CLI flags: budget always applies;
/// `--target-err` and `--deadline-ms` are listed first so their reasons
/// win the report when several criteria hold at once.
fn stopping_rule(args: &Args, cols: usize) -> StoppingRule {
    let mut rule = StoppingRule::new();
    if let Some(t) = args.get("target-err") {
        let target: f64 = t.parse().unwrap_or_else(|_| {
            panic!("--target-err expects a number, got '{t}'")
        });
        rule = rule.with(StoppingCriterion::ErrorBelow(target));
    }
    if let Some(ms) = args.get("deadline-ms") {
        let ms: u64 = ms.parse().unwrap_or_else(|_| {
            panic!("--deadline-ms expects an integer, got '{ms}'")
        });
        rule = rule.with(StoppingCriterion::Deadline(Duration::from_millis(ms)));
    }
    rule.with(StoppingCriterion::ColumnBudget(cols))
}


fn report_approximate(
    args: &Args,
    ds: &Dataset,
    method: &str,
    approx: &NystromApprox,
    err: f64,
    stop: Option<StopReason>,
) {
    if args.flag("json") {
        let mut fields = vec![
            ("dataset", Json::Str(args.get_or("dataset", "two-moons"))),
            ("n", Json::Num(ds.n() as f64)),
            ("dim", Json::Num(ds.dim() as f64)),
            ("method", Json::Str(method.to_string())),
            ("k", Json::Num(approx.k() as f64)),
            ("error", Json::Num(err)),
            ("secs", Json::Num(approx.selection_secs)),
        ];
        if let Some(r) = stop {
            fields.push(("stop", Json::Str(r.as_str().to_string())));
        }
        println!("{}", Json::obj(fields));
    } else {
        let stop_note = stop
            .filter(|&r| r != StopReason::BudgetReached)
            .map(|r| format!(" stop={}", r.as_str()))
            .unwrap_or_default();
        println!(
            "dataset={} n={} dim={} method={} cols={} error={:.3e} select_time={}{}",
            args.get_or("dataset", "two-moons"),
            ds.n(),
            ds.dim(),
            method,
            approx.k(),
            err,
            fmt_secs(approx.selection_secs),
            stop_note,
        );
    }
}

fn cmd_approximate(args: &Args) -> i32 {
    let ds = make_dataset(args);
    let cols = args.usize_or("cols", 450).min(ds.n());
    let seed = args.u64_or("seed", 7);
    let kernel_name = args.get_or("kernel", "gaussian");
    let sigma_frac = args.f64_or("sigma-frac", 0.05);
    let gaussian;
    let linear;
    let kernel: &dyn Kernel = if kernel_name == "linear" {
        linear = Linear;
        &linear
    } else {
        gaussian = Gaussian::with_sigma_fraction(&ds, sigma_frac);
        &gaussian
    };
    let oracle = ImplicitOracle::new(&ds, kernel);
    let method = args.get_or("method", "oasis");
    let mut stop: Option<StopReason> = None;

    let approx = if args.flag("accel") && method == "oasis" {
        let rule = stopping_rule(args, cols);
        let accel_run = Accel::try_default()
            .ok_or_else(|| {
                oasis::anyhow!("no artifacts found (run `make artifacts`)")
            })
            .and_then(|mut accel| {
                let sampler = oasis::runtime::accel::PjrtOasis::new(
                    cols,
                    10.min(cols),
                    1e-12,
                    seed,
                );
                let mut s = sampler.session(&mut accel, &oracle)?;
                let reason = run_to_completion(&mut s, &rule)?;
                Ok((s.snapshot()?, reason))
            });
        match accel_run {
            Ok((a, reason)) => {
                stop = Some(reason);
                a
            }
            Err(e) => {
                eprintln!("accel path failed ({e}); falling back to native");
                let mut s = Oasis::new(cols, 10.min(cols), 1e-12, seed)
                    .session(&oracle)
                    .expect("native oasis");
                stop = Some(
                    run_to_completion(&mut s, &rule).expect("native oasis"),
                );
                s.snapshot().expect("native oasis")
            }
        }
    } else if method == "oasis" || method == "farahat" {
        // sequential samplers run as sessions so --target-err and
        // --deadline-ms can stop them before the column budget
        let rule = stopping_rule(args, cols);
        let result = (|| -> oasis::Result<NystromApprox> {
            if method == "oasis" {
                let mut s =
                    Oasis::new(cols, 10.min(cols), 1e-12, seed).session(&oracle)?;
                stop = Some(run_to_completion(&mut s, &rule)?);
                s.snapshot()
            } else {
                let mut s = Farahat::new(cols).session(&oracle)?;
                stop = Some(run_to_completion(&mut s, &rule)?);
                s.snapshot()
            }
        })();
        match result {
            Ok(a) => a,
            Err(e) => {
                eprintln!("sampling failed: {e}");
                return 1;
            }
        }
    } else {
        let sampler: Box<dyn ColumnSampler> = match method.as_str() {
            "random" => Box::new(Uniform::new(cols, seed)),
            "leverage" => Box::new(LeverageScores::new(cols, cols, seed)),
            "kmeans" => Box::new(KMeansNystrom::new(&ds, kernel, cols, seed)),
            other => {
                eprintln!("unknown method '{other}'");
                return 2;
            }
        };
        match sampler.sample(&oracle) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("sampling failed: {e}");
                return 1;
            }
        }
    };

    let mode = args.get_or("error", if ds.n() <= 8000 { "full" } else { "sampled" });
    let err = if mode == "full" {
        relative_frobenius_error(&oracle, &approx)
    } else {
        sampled_relative_error(&oracle, &approx, 100_000, seed ^ 0xE44)
    };
    report_approximate(args, &ds, &method, &approx, err, stop);
    0
}

fn cmd_parallel(args: &Args) -> i32 {
    let ds = make_dataset(args);
    let cols = args.usize_or("cols", 500).min(ds.n());
    let workers = args.usize_or("workers", 8);
    let seed = args.u64_or("seed", 7);
    let sigma_frac = args.f64_or("sigma-frac", 0.05);
    let kernel: Arc<dyn Kernel + Send + Sync> =
        Arc::new(Gaussian::with_sigma_fraction(&ds, sigma_frac));
    let cfg = OasisPConfig::new(cols, 10.min(cols), workers)
        .with_seed(seed)
        .with_tol(args.f64_or("tol", 1e-12));
    match run_oasis_p(&ds, kernel.clone(), &cfg) {
        Ok((approx, report)) => {
            let gaussian = Gaussian::with_sigma_fraction(&ds, sigma_frac);
            let oracle = ImplicitOracle::new(&ds, &gaussian);
            let err = sampled_relative_error(&oracle, &approx, 100_000, seed ^ 0xE44);
            println!(
                "oASIS-P n={} workers={} cols={} error={:.3e} wall={} [{}]",
                ds.n(),
                report.workers,
                approx.k(),
                err,
                fmt_secs(report.wall_secs),
                report.metrics.summary(),
            );
            0
        }
        Err(e) => {
            eprintln!("oASIS-P failed: {e}");
            1
        }
    }
}

fn cmd_seed(args: &Args) -> i32 {
    use oasis::seed::{css_projection_error, Seed, SeedConfig};
    let ds = make_dataset(args);
    let cfg = SeedConfig {
        dict_size: args.usize_or("dict", 50).min(ds.n()),
        sparsity: args.usize_or("sparsity", 5),
        tol_sq: 1e-12,
        seed: args.u64_or("seed", 7),
    };
    match Seed::decompose(&ds, &cfg) {
        Ok(seed) => {
            println!(
                "SEED: n={} dict={} sparsity≤{} reconstruction={:.3e} eq7={:.3e}",
                ds.n(),
                seed.dictionary.len(),
                cfg.sparsity,
                seed.relative_error,
                css_projection_error(&ds, &seed.dictionary),
            );
            if let Some(kc) = args.get("clusters") {
                let k: usize = kc.parse().unwrap_or(2);
                let labels =
                    oasis::seed::spectral_cluster(&seed.affinity(), k, cfg.seed);
                let mut counts = vec![0usize; k];
                for &l in &labels {
                    counts[l] += 1;
                }
                println!("cluster sizes: {counts:?}");
            }
            0
        }
        Err(e) => {
            eprintln!("SEED failed: {e}");
            1
        }
    }
}

/// Host the approximation server. Prints one "listening" line (with the
/// resolved port — useful with `--port 0`) and serves until
/// `POST /shutdown`.
fn cmd_serve(args: &Args) -> i32 {
    let host = args.get_or("host", "127.0.0.1");
    let port = args.usize_or("port", 7437);
    if port > u16::MAX as usize {
        eprintln!("--port must be ≤ {}", u16::MAX);
        return 2;
    }
    let server = match oasis::server::Server::bind(&format!("{host}:{port}")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("serve: could not bind {host}:{port}: {e}");
            return 1;
        }
    };
    match server.local_addr() {
        Ok(addr) => println!("oasis serve listening on http://{addr}"),
        Err(e) => {
            eprintln!("serve: no local address: {e}");
            return 1;
        }
    }
    match server.run() {
        Ok(()) => {
            println!("oasis serve stopped");
            0
        }
        Err(e) => {
            eprintln!("serve failed: {e}");
            1
        }
    }
}

fn cmd_info() -> i32 {
    match Manifest::load(&Manifest::default_dir()) {
        Ok(m) => {
            println!("artifacts ({}):", m.artifacts.len());
            for a in &m.artifacts {
                println!(
                    "  {:30} op={:18} dims={:?}",
                    a.name, a.op, a.dims
                );
            }
        }
        Err(e) => println!("no artifact manifest: {e}"),
    }
    match oasis::runtime::Executor::cpu() {
        Ok(ex) => println!("PJRT platform: {}", ex.platform()),
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    0
}
