//! `oasis` — CLI for the oASIS kernel-matrix approximation library.
//!
//! Subcommands:
//!   approximate  run one sampler on one dataset, report error + runtime
//!                (optionally save the result as a stored artifact)
//!   query        answer out-of-sample extensions from a stored artifact
//!                without the original dataset or kernel oracle
//!   parallel     run the distributed oASIS-P coordinator
//!   serve        host concurrent resumable sessions over HTTP/JSON
//!   info         show the artifact manifest and PJRT platform
//!
//! Examples:
//!   oasis approximate --dataset two-moons --n 2000 --cols 450 --method oasis
//!   oasis approximate --data points.csv --cols 100 --save model.oasis
//!   oasis query --load model.oasis --points "0.5,0.2;1.0,-0.3" --targets 0,5
//!   oasis parallel --dataset two-moons --n 100000 --cols 500 --workers 8
//!   oasis serve --port 7437 --fs-root .
//!   oasis info

use oasis::coordinator::{run_oasis_p, OasisPConfig};
use oasis::data::{generators, loader, Dataset, LoadLimits};
use oasis::kernels::{Gaussian, Kernel, Linear};
use oasis::nystrom::{
    relative_frobenius_error, sampled_relative_error, NystromApprox,
    Provenance, StoredArtifact,
};
use oasis::runtime::{Accel, Manifest};
use oasis::sampling::{
    farahat::Farahat, kmeans::KMeansNystrom, leverage::LeverageScores,
    oasis::Oasis, run_to_completion, uniform::Uniform, ColumnSampler,
    ImplicitOracle, SamplerSession, StopReason, StoppingCriterion, StoppingRule,
};
use oasis::util::args::Args;
use oasis::util::json::Json;
use oasis::util::timing::fmt_secs;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match cmd {
        "approximate" => cmd_approximate(&args),
        "query" => cmd_query(&args),
        "parallel" => cmd_parallel(&args),
        "seed" => cmd_seed(&args),
        "serve" => cmd_serve(&args),
        "info" => cmd_info(),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "oasis — adaptive column sampling for kernel matrix approximation\n\
         \n\
         USAGE: oasis <approximate|query|parallel|serve|info> [options]\n\
         \n\
         approximate options:\n\
           --dataset   two-moons|abalone|borg|mnist|salinas|lightfield (default two-moons)\n\
           --data      load the dataset from a file instead (CSV or\n\
                       oasis-matrix binary; overrides --dataset/--n)\n\
           --save      write the finished approximation as a stored\n\
                       artifact (indices, factors, selected points,\n\
                       kernel — see oasis::nystrom::store)\n\
           --n         dataset size (default 2000)\n\
           --cols      columns to sample ℓ (default 450)\n\
           --method    oasis|random|leverage|farahat|kmeans (default oasis)\n\
           --kernel    gaussian|linear (default gaussian)\n\
           --sigma-frac  σ as fraction of max pairwise distance (default 0.05)\n\
           --error     full|sampled (default full for n ≤ 8000)\n\
           --seed      RNG seed (default 7)\n\
           --accel     use the PJRT artifact path for oASIS scoring\n\
           --target-err  stop once the estimated relative error reaches\n\
                         this (oasis/farahat; may stop before --cols)\n\
           --deadline-ms stop selection after this many milliseconds\n\
                         (oasis/farahat)\n\
           --json      structured one-line JSON output (method, k,\n\
                       error, secs, stop)\n\
         \n\
         query options (serve a stored artifact, no oracle needed):\n\
           --load      artifact file written by approximate --save or the\n\
                       server's POST /sessions/{{name}}/save (required)\n\
           --points    query points \"x,y;x,y;…\" (omit for a summary)\n\
           --targets   row indices i to evaluate ĝ(z, i) at, \"0,5,11\"\n\
           --json      structured one-line JSON output\n\
         \n\
         parallel options:\n\
           --dataset/--n/--cols/--sigma-frac/--seed as above\n\
           --data      dataset from a file, as in approximate\n\
           --workers   node count p (default 8)\n\
           --tol       stopping tolerance (default 1e-12)\n\
         \n\
         seed options (SEED decomposition, §II-E):\n\
           --dataset/--n/--seed as above\n\
           --dict      dictionary size L (default 50)\n\
           --sparsity  per-point OMP budget (default 5)\n\
           --clusters  if set, spectral-cluster the codes into this many groups\n\
         \n\
         serve options (HTTP/JSON session server; protocol reference in\n\
         the oasis::server module docs):\n\
           --host      bind address (default 127.0.0.1)\n\
           --port      TCP port; 0 picks an ephemeral port, printed on\n\
                       the \"listening\" line (default 7437)\n\
           --fs-root   directory under which client-supplied paths\n\
                       (dataset files, artifact save/load) resolve\n\
                       (default \".\")\n"
    );
}

fn make_dataset(args: &Args) -> Dataset {
    if let Some(path) = args.get("data") {
        match loader::load_dataset(Path::new(path), &LoadLimits::unlimited()) {
            Ok(ds) => return ds,
            Err(e) => {
                eprintln!("could not load --data {path}: {e}");
                std::process::exit(2);
            }
        }
    }
    let name = args.get_or("dataset", "two-moons");
    let n = args.usize_or("n", 2000);
    // XOR so dataset and sampler RNG streams differ for the same --seed
    // (the server passes seeds raw; see generators::by_name)
    let seed = args.u64_or("seed", 7) ^ 0xDA7A;
    match generators::by_name(&name, n, 0, 0.05, seed) {
        Some(ds) => ds,
        None => {
            eprintln!("unknown dataset '{name}'");
            std::process::exit(2);
        }
    }
}

/// Label for report lines and artifact provenance: the file path when
/// `--data` is given, else the generator spelling.
fn dataset_label(args: &Args) -> String {
    match args.get("data") {
        Some(p) => format!("file:{p}"),
        None => args.get_or("dataset", "two-moons"),
    }
}

/// Build the stopping rule from the CLI flags: budget always applies;
/// `--target-err` and `--deadline-ms` are listed first so their reasons
/// win the report when several criteria hold at once.
fn stopping_rule(args: &Args, cols: usize) -> StoppingRule {
    let mut rule = StoppingRule::new();
    if let Some(t) = args.get("target-err") {
        let target: f64 = t.parse().unwrap_or_else(|_| {
            panic!("--target-err expects a number, got '{t}'")
        });
        rule = rule.with(StoppingCriterion::ErrorBelow(target));
    }
    if let Some(ms) = args.get("deadline-ms") {
        let ms: u64 = ms.parse().unwrap_or_else(|_| {
            panic!("--deadline-ms expects an integer, got '{ms}'")
        });
        rule = rule.with(StoppingCriterion::Deadline(Duration::from_millis(ms)));
    }
    rule.with(StoppingCriterion::ColumnBudget(cols))
}


fn report_approximate(
    args: &Args,
    ds: &Dataset,
    method: &str,
    approx: &NystromApprox,
    err: f64,
    stop: Option<StopReason>,
) {
    if args.flag("json") {
        let mut fields = vec![
            ("dataset", Json::Str(dataset_label(args))),
            ("n", Json::Num(ds.n() as f64)),
            ("dim", Json::Num(ds.dim() as f64)),
            ("method", Json::Str(method.to_string())),
            ("k", Json::Num(approx.k() as f64)),
            ("error", Json::Num(err)),
            ("secs", Json::Num(approx.selection_secs)),
        ];
        if let Some(r) = stop {
            fields.push(("stop", Json::Str(r.as_str().to_string())));
        }
        println!("{}", Json::obj(fields));
    } else {
        let stop_note = stop
            .filter(|&r| r != StopReason::BudgetReached)
            .map(|r| format!(" stop={}", r.as_str()))
            .unwrap_or_default();
        println!(
            "dataset={} n={} dim={} method={} cols={} error={:.3e} select_time={}{}",
            dataset_label(args),
            ds.n(),
            ds.dim(),
            method,
            approx.k(),
            err,
            fmt_secs(approx.selection_secs),
            stop_note,
        );
    }
}

fn cmd_approximate(args: &Args) -> i32 {
    let ds = make_dataset(args);
    let cols = args.usize_or("cols", 450).min(ds.n());
    let seed = args.u64_or("seed", 7);
    let kernel_name = args.get_or("kernel", "gaussian");
    let sigma_frac = args.f64_or("sigma-frac", 0.05);
    let gaussian;
    let linear;
    let kernel: &dyn Kernel = if kernel_name == "linear" {
        linear = Linear;
        &linear
    } else {
        gaussian = Gaussian::with_sigma_fraction(&ds, sigma_frac);
        &gaussian
    };
    let oracle = ImplicitOracle::new(&ds, kernel);
    let method = args.get_or("method", "oasis");
    let mut stop: Option<StopReason> = None;

    let approx = if args.flag("accel") && method == "oasis" {
        let rule = stopping_rule(args, cols);
        let accel_run = Accel::try_default()
            .ok_or_else(|| {
                oasis::anyhow!("no artifacts found (run `make artifacts`)")
            })
            .and_then(|mut accel| {
                let sampler = oasis::runtime::accel::PjrtOasis::new(
                    cols,
                    10.min(cols),
                    1e-12,
                    seed,
                );
                let mut s = sampler.session(&mut accel, &oracle)?;
                let reason = run_to_completion(&mut s, &rule)?;
                Ok((s.snapshot()?, reason))
            });
        match accel_run {
            Ok((a, reason)) => {
                stop = Some(reason);
                a
            }
            Err(e) => {
                eprintln!("accel path failed ({e}); falling back to native");
                let mut s = Oasis::new(cols, 10.min(cols), 1e-12, seed)
                    .session(&oracle)
                    .expect("native oasis");
                stop = Some(
                    run_to_completion(&mut s, &rule).expect("native oasis"),
                );
                s.snapshot().expect("native oasis")
            }
        }
    } else if method == "oasis" || method == "farahat" {
        // sequential samplers run as sessions so --target-err and
        // --deadline-ms can stop them before the column budget
        let rule = stopping_rule(args, cols);
        let result = (|| -> oasis::Result<NystromApprox> {
            if method == "oasis" {
                let mut s =
                    Oasis::new(cols, 10.min(cols), 1e-12, seed).session(&oracle)?;
                stop = Some(run_to_completion(&mut s, &rule)?);
                s.snapshot()
            } else {
                let mut s = Farahat::new(cols).session(&oracle)?;
                stop = Some(run_to_completion(&mut s, &rule)?);
                s.snapshot()
            }
        })();
        match result {
            Ok(a) => a,
            Err(e) => {
                eprintln!("sampling failed: {e}");
                return 1;
            }
        }
    } else {
        let sampler: Box<dyn ColumnSampler> = match method.as_str() {
            "random" => Box::new(Uniform::new(cols, seed)),
            "leverage" => Box::new(LeverageScores::new(cols, cols, seed)),
            "kmeans" => Box::new(KMeansNystrom::new(&ds, kernel, cols, seed)),
            other => {
                eprintln!("unknown method '{other}'");
                return 2;
            }
        };
        match sampler.sample(&oracle) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("sampling failed: {e}");
                return 1;
            }
        }
    };

    let mode = args.get_or("error", if ds.n() <= 8000 { "full" } else { "sampled" });
    let err = if mode == "full" {
        relative_frobenius_error(&oracle, &approx)
    } else {
        sampled_relative_error(&oracle, &approx, 100_000, seed ^ 0xE44)
    };
    report_approximate(args, &ds, &method, &approx, err, stop);
    if let Some(out) = args.get("save") {
        // selected points + resolved kernel ride along, so `oasis query
        // --load` can answer extensions without this dataset. Runs after
        // the report so the approximation moves into the artifact
        // instead of being cloned (C alone is n×k).
        let save = StoredArtifact::from_parts(
            approx,
            &ds,
            kernel,
            Provenance { source: dataset_label(args), method: method.clone() },
            Some(err),
        )
        .and_then(|artifact| artifact.save(Path::new(out)));
        match save {
            // stderr so `--json` stdout stays a single parseable line
            Ok(bytes) => eprintln!("saved artifact to {out} ({bytes} bytes)"),
            Err(e) => {
                eprintln!("--save {out} failed: {e}");
                return 1;
            }
        }
    }
    0
}

/// Serve extension queries from a stored artifact — no dataset, no
/// kernel oracle, just the file written by `approximate --save` or the
/// server's save endpoint.
fn cmd_query(args: &Args) -> i32 {
    let path = match args.get("load") {
        Some(p) => p,
        None => {
            eprintln!("query requires --load <artifact file>");
            return 2;
        }
    };
    let artifact = match StoredArtifact::load(Path::new(path)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("query: {e}");
            return 1;
        }
    };
    let points = match args.get("points").map(parse_points) {
        None => Vec::new(),
        Some(Ok(p)) => p,
        Some(Err(e)) => {
            eprintln!("--points: {e}");
            return 2;
        }
    };
    let targets = match args.get("targets").map(parse_indices) {
        None => Vec::new(),
        Some(Ok(t)) => t,
        Some(Err(e)) => {
            eprintln!("--targets: {e}");
            return 2;
        }
    };
    if points.is_empty() {
        // no query points: report what the artifact holds
        if args.flag("json") {
            println!("{}", artifact.summary_json());
        } else {
            println!(
                "artifact {path}: n={} k={} dim={} kernel={} method={} \
                 source={} error_estimate={}",
                artifact.n(),
                artifact.k(),
                artifact.dim(),
                artifact.kernel.name(),
                artifact.provenance.method,
                artifact.provenance.source,
                artifact
                    .error_estimate
                    .map(|e| format!("{e:.3e}"))
                    .unwrap_or_else(|| "n/a".into()),
            );
        }
        return 0;
    }
    let mut results = Vec::with_capacity(points.len());
    for (i, z) in points.iter().enumerate() {
        let w = match artifact.query_weights(z) {
            Ok(w) => w,
            Err(e) => {
                eprintln!("query point {i}: {e}");
                return 1;
            }
        };
        let vals = match artifact.extend(&w, &targets) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("query point {i}: {e}");
                return 1;
            }
        };
        results.push((w, vals));
    }
    if args.flag("json") {
        let arr: Vec<Json> = results
            .iter()
            .map(|(w, vals)| {
                let mut fields = vec![(
                    "weights",
                    Json::Arr(w.iter().map(|&x| Json::Num(x)).collect()),
                )];
                if !targets.is_empty() {
                    fields.push((
                        "kernel",
                        Json::Arr(vals.iter().map(|&x| Json::Num(x)).collect()),
                    ));
                }
                Json::obj(fields)
            })
            .collect();
        println!(
            "{}",
            Json::obj(vec![
                ("k", Json::Num(artifact.k() as f64)),
                ("results", Json::Arr(arr)),
            ])
        );
    } else {
        for (i, (w, vals)) in results.iter().enumerate() {
            if targets.is_empty() {
                let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
                println!("point {i}: weights k={} ‖w‖={norm:.6e}", w.len());
            } else {
                let rendered: Vec<String> = targets
                    .iter()
                    .zip(vals)
                    .map(|(t, v)| format!("g({t})={v:.6e}"))
                    .collect();
                println!("point {i}: {}", rendered.join(" "));
            }
        }
    }
    0
}

/// Parse `"x,y;x,y;…"` into query points.
fn parse_points(s: &str) -> Result<Vec<Vec<f64>>, String> {
    let mut out = Vec::new();
    for (i, part) in s.split(';').enumerate() {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let mut row = Vec::new();
        for field in part.split(',') {
            let x: f64 = field
                .trim()
                .parse()
                .map_err(|_| format!("point {i}: {field:?} is not a number"))?;
            // same rule as the server's query parser and the CSV loader
            if !x.is_finite() {
                return Err(format!("point {i}: {field:?} is not finite"));
            }
            row.push(x);
        }
        out.push(row);
    }
    if out.is_empty() {
        return Err("no points given".into());
    }
    Ok(out)
}

/// Parse `"0,5,11"` into row indices.
fn parse_indices(s: &str) -> Result<Vec<usize>, String> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| {
            t.parse::<usize>()
                .map_err(|_| format!("{t:?} is not an index"))
        })
        .collect()
}

fn cmd_parallel(args: &Args) -> i32 {
    let ds = make_dataset(args);
    let cols = args.usize_or("cols", 500).min(ds.n());
    let workers = args.usize_or("workers", 8);
    let seed = args.u64_or("seed", 7);
    let sigma_frac = args.f64_or("sigma-frac", 0.05);
    let kernel: Arc<dyn Kernel + Send + Sync> =
        Arc::new(Gaussian::with_sigma_fraction(&ds, sigma_frac));
    let cfg = OasisPConfig::new(cols, 10.min(cols), workers)
        .with_seed(seed)
        .with_tol(args.f64_or("tol", 1e-12));
    match run_oasis_p(&ds, kernel.clone(), &cfg) {
        Ok((approx, report)) => {
            let gaussian = Gaussian::with_sigma_fraction(&ds, sigma_frac);
            let oracle = ImplicitOracle::new(&ds, &gaussian);
            let err = sampled_relative_error(&oracle, &approx, 100_000, seed ^ 0xE44);
            println!(
                "oASIS-P n={} workers={} cols={} error={:.3e} wall={} [{}]",
                ds.n(),
                report.workers,
                approx.k(),
                err,
                fmt_secs(report.wall_secs),
                report.metrics.summary(),
            );
            0
        }
        Err(e) => {
            eprintln!("oASIS-P failed: {e}");
            1
        }
    }
}

fn cmd_seed(args: &Args) -> i32 {
    use oasis::seed::{css_projection_error, Seed, SeedConfig};
    let ds = make_dataset(args);
    let cfg = SeedConfig {
        dict_size: args.usize_or("dict", 50).min(ds.n()),
        sparsity: args.usize_or("sparsity", 5),
        tol_sq: 1e-12,
        seed: args.u64_or("seed", 7),
    };
    match Seed::decompose(&ds, &cfg) {
        Ok(seed) => {
            println!(
                "SEED: n={} dict={} sparsity≤{} reconstruction={:.3e} eq7={:.3e}",
                ds.n(),
                seed.dictionary.len(),
                cfg.sparsity,
                seed.relative_error,
                css_projection_error(&ds, &seed.dictionary),
            );
            if let Some(kc) = args.get("clusters") {
                let k: usize = kc.parse().unwrap_or(2);
                let labels =
                    oasis::seed::spectral_cluster(&seed.affinity(), k, cfg.seed);
                let mut counts = vec![0usize; k];
                for &l in &labels {
                    counts[l] += 1;
                }
                println!("cluster sizes: {counts:?}");
            }
            0
        }
        Err(e) => {
            eprintln!("SEED failed: {e}");
            1
        }
    }
}

/// Host the approximation server. Prints one "listening" line (with the
/// resolved port — useful with `--port 0`) and serves until
/// `POST /shutdown`.
fn cmd_serve(args: &Args) -> i32 {
    let host = args.get_or("host", "127.0.0.1");
    let port = args.usize_or("port", 7437);
    if port > u16::MAX as usize {
        eprintln!("--port must be ≤ {}", u16::MAX);
        return 2;
    }
    let fs_root = std::path::PathBuf::from(args.get_or("fs-root", "."));
    if !fs_root.is_dir() {
        eprintln!("serve: --fs-root {} is not a directory", fs_root.display());
        return 2;
    }
    let config = oasis::server::ServerConfig { fs_root };
    let server =
        match oasis::server::Server::bind_with(&format!("{host}:{port}"), config) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("serve: could not bind {host}:{port}: {e}");
                return 1;
            }
        };
    match server.local_addr() {
        Ok(addr) => println!("oasis serve listening on http://{addr}"),
        Err(e) => {
            eprintln!("serve: no local address: {e}");
            return 1;
        }
    }
    match server.run() {
        Ok(()) => {
            println!("oasis serve stopped");
            0
        }
        Err(e) => {
            eprintln!("serve failed: {e}");
            1
        }
    }
}

fn cmd_info() -> i32 {
    match Manifest::load(&Manifest::default_dir()) {
        Ok(m) => {
            println!("artifacts ({}):", m.artifacts.len());
            for a in &m.artifacts {
                println!(
                    "  {:30} op={:18} dims={:?}",
                    a.name, a.op, a.dims
                );
            }
        }
        Err(e) => println!("no artifact manifest: {e}"),
    }
    match oasis::runtime::Executor::cpu() {
        Ok(ex) => println!("PJRT platform: {}", ex.platform()),
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    0
}
