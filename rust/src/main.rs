//! `oasis` — CLI for the oASIS kernel-matrix approximation library.
//!
//! Subcommands:
//!   approximate  run one sampler on one dataset, report error + runtime
//!                (optionally save the result as a stored artifact)
//!   query        answer out-of-sample extensions from a stored artifact
//!                without the original dataset or kernel oracle
//!   task         fit and run a downstream task (KRR, kernel PCA,
//!                spectral clustering) on an approximation — from a
//!                fresh run or a stored artifact (dataset-free)
//!   parallel     run the distributed oASIS-P coordinator (in-process
//!                workers, or a TCP leader with --listen)
//!   worker       join a TCP leader as one oASIS-P worker process
//!   export       write a dataset as an oasis-matrix binary file (the
//!                format --shard-reads workers seek into)
//!   serve        host concurrent resumable sessions over HTTP/JSON
//!   info         show the artifact manifest and PJRT platform
//!
//! Examples:
//!   oasis approximate --dataset two-moons --n 2000 --cols 450 --method oasis
//!   oasis approximate --data points.csv --cols 100 --save model.oasis
//!   oasis query --load model.oasis --points "0.5,0.2;1.0,-0.3" --targets 0,5
//!   oasis task --task krr --load model.oasis --labels y.csv --predict new.csv
//!   oasis parallel --dataset two-moons --n 100000 --cols 500 --workers 8
//!   oasis serve --port 7437 --fs-root .
//!   oasis info

use oasis::data::{Dataset, LoadLimits};
use oasis::engine::{
    self, DatasetSpec, KernelSpec, LabelsSpec, Method, MethodSpec, ResolvedRun,
    RunSpec, SessionBuilder, TaskSpec, WarmStartSpec,
};
use oasis::nystrom::{
    relative_frobenius_error, sampled_relative_error, NystromApprox,
    Provenance, StoredArtifact,
};
use oasis::runtime::{Accel, Manifest};
use oasis::sampling::{
    run_to_completion, run_to_completion_observed, SamplerSession, StopReason,
};
use oasis::tasks::{FittedTask, TaskKind};
use oasis::util::args::Args;
use oasis::util::json::Json;
use oasis::util::timing::fmt_secs;
use std::path::{Path, PathBuf};

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match cmd {
        "approximate" => cmd_approximate(&args),
        "query" => cmd_query(&args),
        "task" => cmd_task(&args),
        "parallel" => cmd_parallel(&args),
        "worker" => cmd_worker(&args),
        "export" => cmd_export(&args),
        "seed" => cmd_seed(&args),
        "serve" => cmd_serve(&args),
        "bench-serve" => cmd_bench_serve(&args),
        "promcheck" => cmd_promcheck(&args),
        "info" => cmd_info(),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "oasis — adaptive column sampling for kernel matrix approximation\n\
         \n\
         USAGE: oasis <approximate|query|task|parallel|worker|export|\n\
                       serve|bench-serve|promcheck|info> [options]\n\
         \n\
         approximate options:\n\
           --dataset   two-moons|abalone|borg|mnist|salinas|lightfield (default two-moons)\n\
           --data      load the dataset from a file instead (CSV or\n\
                       oasis-matrix binary; overrides --dataset/--n)\n\
           --save      write the finished approximation as a stored\n\
                       artifact (indices, factors, selected points,\n\
                       kernel — see oasis::nystrom::store)\n\
           --save-f32  with --save: encode the C/W⁻¹ factor payload as\n\
                       f32 (about half the bytes; lossy — reloaded\n\
                       factors, extension queries, and task fits then\n\
                       carry f32 precision. Selected points stay f64,\n\
                       so warm starts still verify exactly)\n\
           --n         dataset size (default 2000)\n\
           --cols      columns to sample ℓ (default 450)\n\
           --method    oasis|sis|farahat|icd|adaptive-random|oasis-p|\n\
                       random|leverage|kmeans (default oasis)\n\
           --kernel    gaussian|linear (default gaussian)\n\
           --sigma     explicit Gaussian σ (overrides --sigma-frac)\n\
           --sigma-frac  σ as fraction of max pairwise distance (default 0.05)\n\
           --error     full|sampled (default full for n ≤ 8000)\n\
           --seed      RNG seed (default 7)\n\
           --resume-from  warm-start selection from a stored artifact's\n\
                       Λ (oasis and sis methods; the artifact's dataset/\n\
                       kernel must match this run's — checked; bit-exact\n\
                       resume additionally needs the original run's\n\
                       init_cols)\n\
           --accel     use the PJRT artifact path for oASIS scoring\n\
           --target-err  stop once the estimated relative error reaches\n\
                         this (oasis/farahat; may stop before --cols)\n\
           --deadline-ms stop selection after this many milliseconds\n\
                         (oasis/farahat)\n\
           --json      structured one-line JSON output (method, k,\n\
                       error, secs, stop)\n\
           --trace     FILE — record the run's internal phases (score\n\
                       scan, column fetch, factor update, …) and write\n\
                       them as Chrome trace_event JSON (load at\n\
                       chrome://tracing or ui.perfetto.dev); also prints\n\
                       a per-phase timing table\n\
           --trajectory  FILE — write the convergence trajectory as CSV:\n\
                       one step,k,index,score,error_estimate,step_us row\n\
                       per selection (session methods only; the offline\n\
                       twin of GET /sessions/{{name}}/trajectory)\n\
         \n\
         query options (serve a stored artifact, no oracle needed):\n\
           --load      artifact file written by approximate --save or the\n\
                       server's POST /sessions/{{name}}/save (required)\n\
           --points    query points \"x,y;x,y;…\" (omit for a summary)\n\
           --targets   row indices i to evaluate ĝ(z, i) at, \"0,5,11\"\n\
           --json      structured one-line JSON output\n\
         \n\
         task options (downstream tasks on an approximation):\n\
           --task      krr|kpca|cluster (default krr)\n\
           --load      fit from a stored artifact — dataset-free; without\n\
                       --labels, a krr model stored in the artifact is\n\
                       reused as-is. Omit --load to run a fresh\n\
                       approximation first (same flags as approximate)\n\
           --labels    CSV/binary file with one training label per data\n\
                       point (krr; --label-col picks the column(s))\n\
           --label-col column index, list, or range — \"0\", \"0,2\",\n\
                       \"1-3\", \"0,2-4\" (default 0). Several columns fit\n\
                       one multi-output krr model: all outputs share a\n\
                       single factorization, predictions carry one value\n\
                       per output\n\
           --ridge     krr regularization λ > 0 (default 1e-3)\n\
           --f32       serve --predict through the f32 kernel-block path\n\
                       (krr only; single-precision results, ~1e-6\n\
                       relative error — measurably faster on large\n\
                       batches, never bit-identical to the f64 path)\n\
           --components  embedding dimensions (kpca/cluster; default\n\
                       2, cluster defaults to --clusters)\n\
           --clusters  cluster count (cluster; default 2)\n\
           --predict   CSV/binary file of query points to predict for\n\
                       (krr value / kpca embedding / cluster label per\n\
                       point — evaluates only the k selected points)\n\
           --save      write the artifact back with the fitted task\n\
                       model attached (versioned task section; a later\n\
                       `oasis task --load` can predict without labels)\n\
           --json      structured one-line JSON output\n\
           --trace     FILE — Chrome trace of the fit/predict phases,\n\
                       as in approximate\n\
         \n\
         parallel options:\n\
           --dataset/--n/--cols/--sigma/--sigma-frac/--seed as above\n\
           --data      dataset from a file, as in approximate\n\
           --workers   node count p (default 8)\n\
           --tol       stopping tolerance (default 1e-12)\n\
           --shard-reads  each worker reads only its own byte range of\n\
                       the binary --data file (the leader never loads\n\
                       the dataset; needs --sigma or a data-free kernel;\n\
                       reports the distributed error estimate)\n\
           --merge-batch  SQUEAK merge width B (default 1): per argmax\n\
                       round the leader admits up to B of the workers'\n\
                       top candidates. 1 reproduces the sequential\n\
                       selection bit for bit; >1 trades selection order\n\
                       for ~B× fewer gather rounds\n\
           --listen    HOST:PORT — become a TCP leader instead of\n\
                       spawning in-process workers: bind, print the\n\
                       join address, and wait for --workers `oasis\n\
                       worker` processes (requires --shard-reads and a\n\
                       binary --data file; port 0 picks one)\n\
           --save      write the finished approximation as a stored\n\
                       artifact, as in approximate\n\
           --trace     FILE — merged fleet trace: the leader's\n\
                       gather/arbitrate/reshard spans on the pid-1\n\
                       track, plus — for TCP fleets — every worker's\n\
                       shard-load/diag/score-scan/column-serve spans on\n\
                       their own per-worker pid tracks (shipped\n\
                       leaderward during the run), one Chrome-loadable\n\
                       timeline for the whole fleet\n\
           --log-level error|warn|info|debug — structured-log threshold\n\
                       (default info)\n\
           --log-json  emit log lines as JSON objects instead of text\n\
         \n\
         worker options (one oASIS-P worker process; framed-TCP wire\n\
         protocol documented in the oasis::coordinator module docs):\n\
           --join      HOST:PORT the leader printed (required). The\n\
                       worker receives its shard assignment, reads its\n\
                       own byte range of the dataset file, and serves\n\
                       argmax/column requests until the run finishes\n\
           --data      read this file instead of the leader's dataset\n\
                       path (for workers whose filesystem mounts the\n\
                       data elsewhere)\n\
           --throttle-ms  sleep this long before each argmax sweep\n\
                       (testing aid: makes mid-run failures easy to\n\
                       inject)\n\
           --trace     FILE — on exit, write this worker's own local\n\
                       spans as Chrome trace_event JSON (independent of\n\
                       the leader's merged --trace)\n\
           --log-level / --log-json  as in parallel\n\
         \n\
         export options (write an oasis-matrix binary file — the only\n\
         format --shard-reads workers can seek byte ranges of):\n\
           --dataset/--n/--seed  generator source, as in approximate\n\
           --data      convert an existing CSV file instead\n\
           --out       destination file (required)\n\
         \n\
         seed options (SEED decomposition, §II-E):\n\
           --dataset/--n/--seed as above\n\
           --dict      dictionary size L (default 50)\n\
           --sparsity  per-point OMP budget (default 5)\n\
           --clusters  if set, spectral-cluster the codes into this many groups\n\
         \n\
         promcheck options (scrape a running server's Prometheus page\n\
         and validate the exposition format — exits non-zero on any\n\
         malformed family/sample, for CI smoke jobs):\n\
           --host      server address (default 127.0.0.1)\n\
           --port      server port (default 7437)\n\
           --require   fail unless the page contains this substring\n\
                       (e.g. a metric family a run must have produced)\n\
         \n\
         serve options (HTTP/JSON session server; protocol reference in\n\
         the oasis::server module docs):\n\
           --host      bind address (default 127.0.0.1)\n\
           --port      TCP port; 0 picks an ephemeral port, printed on\n\
                       the \"listening\" line (default 7437)\n\
           --fs-root   directory under which client-supplied paths\n\
                       (dataset files, artifact save/load) resolve\n\
                       (default \".\")\n\
           --threads   connection worker threads (default: available\n\
                       parallelism); connections queue when all are busy\n\
           --queue     accept-queue depth (default 128); overflow gets\n\
                       a one-shot 503\n\
           --max-rps   global request cap per second (default 0 = off);\n\
                       over-cap requests get 429 (/healthz and /shutdown\n\
                       exempt)\n\
           --max-rps-per-ip  per-client-IP cap per second (default 0)\n\
           --drain-ms  graceful-shutdown drain deadline for in-flight\n\
                       requests (default 5000)\n\
           --log-level error|warn|info|debug — structured-log threshold\n\
                       (default info); every request logs one line\n\
                       carrying its X-Request-Id\n\
           --log-json  emit log lines as JSON objects instead of text\n\
         \n\
         bench-serve options (load-generate against a serve instance and\n\
         report p50/p99 latency + requests/sec for single vs. batched\n\
         predict):\n\
           --host/--port  target server; omit --port to self-host an\n\
                       in-process server on an ephemeral port\n\
           --threads   self-hosted server's worker threads\n\
           --conns     concurrent keep-alive connections (default 8)\n\
           --requests  requests per batch-size sweep point (default 2000)\n\
           --batches   predict batch sizes to sweep, \"1,16,64\"\n\
           --f32       drive the f32 predict path\n\
           --quick     small preset for CI smoke (fewer conns/requests)\n\
           --out       merge a \"serve\" section into this JSON file\n\
                       (e.g. BENCH_ci.json)\n\
           --json      structured one-line JSON output\n"
    );
}

/// The engine dataset spec the CLI flags describe: `--data FILE`, else a
/// generator.
fn dataset_spec(args: &Args) -> DatasetSpec {
    if let Some(path) = args.get("data") {
        DatasetSpec::File { label: path.to_string(), path: PathBuf::from(path) }
    } else {
        DatasetSpec::Generator {
            name: args.get_or("dataset", "two-moons"),
            n: args.usize_or("n", 2000),
            // XOR so dataset and sampler RNG streams differ for the same
            // --seed (the server passes seeds raw; see generators::by_name)
            seed: args.u64_or("seed", 7) ^ 0xDA7A,
            noise: 0.05,
            dim: 0,
        }
    }
}

/// The engine kernel spec: `--kernel linear`, or a Gaussian with
/// `--sigma` (explicit, required by `--shard-reads`) / `--sigma-frac`.
fn kernel_spec(args: &Args) -> Result<KernelSpec, String> {
    if args.get_or("kernel", "gaussian") == "linear" {
        return Ok(KernelSpec::Linear);
    }
    let sigma = match args.get("sigma") {
        None => None,
        Some(s) => Some(
            s.parse::<f64>()
                .ok()
                .filter(|x| x.is_finite() && *x > 0.0)
                .ok_or_else(|| format!("--sigma expects a number > 0, got '{s}'"))?,
        ),
    };
    Ok(KernelSpec::Gaussian { sigma, sigma_fraction: args.f64_or("sigma-frac", 0.05) })
}

/// Label for report lines and artifact provenance: the file path when
/// `--data` is given, else the generator spelling. (The engine's own
/// `source` is the fully qualified description; the CLI keeps its
/// historical short spelling.)
fn dataset_label(args: &Args) -> String {
    match args.get("data") {
        Some(p) => format!("file:{p}"),
        None => args.get_or("dataset", "two-moons"),
    }
}

/// The full `approximate`/`parallel` run spec from the CLI flags — the
/// same [`RunSpec`] the server parses from a create payload, so both
/// front ends resolve through the identical engine pipeline.
fn run_spec(args: &Args, method: Method, default_cols: usize) -> Result<RunSpec, String> {
    let cols = args.usize_or("cols", default_cols);
    let target_err = match args.get("target-err") {
        None => None,
        Some(t) => Some(
            t.parse::<f64>()
                .map_err(|_| format!("--target-err expects a number, got '{t}'"))?,
        ),
    };
    let deadline_ms = match args.get("deadline-ms") {
        None => None,
        Some(m) => Some(
            m.parse::<u64>()
                .map_err(|_| format!("--deadline-ms expects an integer, got '{m}'"))?,
        ),
    };
    Ok(RunSpec {
        dataset: dataset_spec(args),
        kernel: kernel_spec(args)?,
        method: MethodSpec {
            method,
            max_cols: cols,
            init_cols: 10.min(cols).max(1),
            tol: args.f64_or("tol", 1e-12),
            seed: args.u64_or("seed", 7),
            batch: 10,
            workers: args.usize_or("workers", 8),
            merge_batch: args.usize_or("merge-batch", 1),
            listen: args.get("listen").map(String::from),
        },
        // budget always applies; target/deadline listed first so their
        // reasons win the report when several criteria hold at once
        // (budgets past n are clamped at resolve time)
        stopping: engine::stopping_rule(cols, target_err, deadline_ms),
        shard_reads: args.flag("shard-reads"),
        warm_start: args.get("resume-from").map(|p| WarmStartSpec {
            label: p.to_string(),
            path: PathBuf::from(p),
        }),
    })
}

/// Resolve a spec or exit with the CLI's usage-error code.
fn resolve_or_exit(cmd: &str, spec: RunSpec) -> ResolvedRun {
    match SessionBuilder::new().resolve(spec) {
        Ok(run) => run,
        Err(e) => {
            eprintln!("{cmd}: {e}");
            std::process::exit(2);
        }
    }
}

/// `--log-level LEVEL` / `--log-json`: configure the structured logger
/// (oasis::obs::log) before any subsystem emits. Returns `false` — a
/// usage error — on an unknown level name.
fn log_begin(cmd: &str, args: &Args) -> bool {
    match oasis::obs::log::configure_from_args(
        args.get("log-level"),
        args.flag("log-json"),
    ) {
        Ok(()) => true,
        Err(e) => {
            eprintln!("{cmd}: {e}");
            false
        }
    }
}

/// `--trace FILE`: turn the span recorder on before any engine work so
/// the resolve/sampling/coordinator guards record. Returns the output
/// path for [`trace_export`] at command exit.
fn trace_begin(args: &Args) -> Option<PathBuf> {
    let path = args.get("trace")?;
    oasis::obs::trace::enable();
    Some(PathBuf::from(path))
}

/// The per-phase timing table printed alongside any trace export.
fn phase_table(phases: &[oasis::obs::trace::PhaseStat]) -> String {
    let mut table = String::new();
    if phases.is_empty() {
        return table;
    }
    table.push_str(&format!(
        "{:<16} {:>7} {:>10} {:>10} {:>10} {:>10}\n",
        "phase", "count", "total", "p50", "p99", "max"
    ));
    for p in phases {
        table.push_str(&format!(
            "{:<16} {:>7} {:>10} {:>10} {:>10} {:>10}\n",
            p.name,
            p.hist.count(),
            fmt_secs(p.hist.sum()),
            fmt_secs(p.hist.quantile(0.5)),
            fmt_secs(p.hist.quantile(0.99)),
            fmt_secs(p.hist.max()),
        ));
    }
    table
}

/// Drain the recorder, write the Chrome `trace_event` JSON (atomic —
/// a crash mid-write never leaves a truncated file), and print the
/// per-phase timing table. The table goes to stderr under `--json` so
/// stdout stays one parseable line. Returns the command's exit code
/// contribution (0, or 1 if the trace file could not be written).
fn trace_export(args: &Args, out: Option<PathBuf>) -> i32 {
    let Some(path) = out else { return 0 };
    oasis::obs::trace::disable();
    let trace = oasis::obs::trace::drain();
    let json = trace.to_chrome_json().to_string();
    if let Err(e) = oasis::util::fsio::write_atomic(&path, json.as_bytes()) {
        eprintln!("--trace {}: {e}", path.display());
        return 1;
    }
    let mut table = format!(
        "trace: {} events ({} dropped) written to {}\n",
        trace.events.len(),
        trace.dropped,
        path.display()
    );
    table.push_str(&phase_table(&trace.phase_summary()));
    if args.flag("json") {
        eprint!("{table}");
    } else {
        print!("{table}");
    }
    0
}

/// `parallel --trace`: the fleet-wide merged export. The leader's own
/// drained events become the pid-1 `leader` track; each TCP worker's
/// spans (shipped leaderward as TraceChunk frames during the run) land
/// on their own pid track, so Chrome/Perfetto shows the whole fleet on
/// one timeline. In-process fleets record straight into the leader's
/// ring, so `worker_tracks` is empty there and the export degrades to
/// the single-track shape.
fn trace_export_fleet(
    args: &Args,
    out: Option<PathBuf>,
    worker_tracks: Vec<oasis::obs::trace::TraceTrack>,
) -> i32 {
    let Some(path) = out else { return 0 };
    oasis::obs::trace::disable();
    let trace = oasis::obs::trace::drain();
    let phases = trace.phase_summary();
    let leader_events = trace.events.len();
    let leader_dropped = trace.dropped;
    let mut tracks = vec![trace.into_track(1, "leader")];
    tracks.extend(worker_tracks);
    let json = oasis::obs::trace::merged_chrome_json(&tracks).to_string();
    if let Err(e) = oasis::util::fsio::write_atomic(&path, json.as_bytes()) {
        eprintln!("--trace {}: {e}", path.display());
        return 1;
    }
    let mut table = format!(
        "trace: {leader_events} leader events ({leader_dropped} dropped) + \
         {} worker track(s) written to {}\n",
        tracks.len() - 1,
        path.display()
    );
    table.push_str(&phase_table(&phases));
    if args.flag("json") {
        eprint!("{table}");
    } else {
        print!("{table}");
    }
    0
}

/// Scrape a running server's `GET /metrics?format=prometheus` and
/// validate the exposition with [`oasis::obs::prom::validate`] — the
/// in-repo checker CI's smoke jobs run instead of shipping a real
/// Prometheus binary. `--require` additionally asserts a substring
/// (e.g. a metric family a traffic-generating step must have produced).
fn cmd_promcheck(args: &Args) -> i32 {
    use std::net::ToSocketAddrs;
    let host = args.get_or("host", "127.0.0.1");
    let port = args.usize_or("port", 7437);
    let addr = match format!("{host}:{port}")
        .to_socket_addrs()
        .ok()
        .and_then(|mut a| a.next())
    {
        Some(a) => a,
        None => {
            eprintln!("promcheck: cannot resolve {host}:{port}");
            return 2;
        }
    };
    let (status, body) = match oasis::server::http::client_request(
        addr,
        "GET",
        "/metrics?format=prometheus",
        "",
    ) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("promcheck: request to {addr} failed: {e}");
            return 1;
        }
    };
    if status != 200 {
        eprintln!("promcheck: HTTP {status} from {addr}");
        return 1;
    }
    if let Err(e) = oasis::obs::prom::validate(&body) {
        eprintln!("promcheck: invalid exposition: {e}");
        return 1;
    }
    if let Some(needle) = args.get("require") {
        if !body.contains(needle) {
            eprintln!("promcheck: page lacks required substring '{needle}'");
            return 1;
        }
    }
    let families = body.lines().filter(|l| l.starts_with("# TYPE")).count();
    let samples = body
        .lines()
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .count();
    println!("promcheck ok: {samples} samples across {families} families");
    0
}


/// `approximate --trajectory FILE`: one CSV row per selection step —
/// the offline twin of the server's `GET /sessions/{name}/trajectory`.
/// Unavailable values render as empty fields (error estimates for
/// methods without an estimator, scores for unscored randomized draws).
fn write_trajectory_csv(
    path: &Path,
    records: &[oasis::sampling::StepRecord],
) -> oasis::Result<()> {
    let mut csv =
        String::from("step,k,index,score,error_estimate,step_us\n");
    for r in records {
        let score = if r.score.is_finite() {
            format!("{:e}", r.score)
        } else {
            String::new()
        };
        let err = r
            .error_estimate
            .filter(|e| e.is_finite())
            .map(|e| format!("{e:e}"))
            .unwrap_or_default();
        csv.push_str(&format!(
            "{},{},{},{score},{err},{}\n",
            r.step, r.k, r.index, r.step_us
        ));
    }
    oasis::util::fsio::write_atomic(path, csv.as_bytes())?;
    Ok(())
}

fn report_approximate(
    args: &Args,
    ds: &Dataset,
    method: &str,
    approx: &NystromApprox,
    err: f64,
    stop: Option<StopReason>,
) {
    if args.flag("json") {
        let mut fields = vec![
            ("dataset", Json::Str(dataset_label(args))),
            ("n", Json::Num(ds.n() as f64)),
            ("dim", Json::Num(ds.dim() as f64)),
            ("method", Json::Str(method.to_string())),
            ("k", Json::Num(approx.k() as f64)),
            ("error", Json::Num(err)),
            ("secs", Json::Num(approx.selection_secs)),
        ];
        if let Some(r) = stop {
            fields.push(("stop", Json::Str(r.as_str().to_string())));
        }
        println!("{}", Json::obj(fields));
    } else {
        let stop_note = stop
            .filter(|&r| r != StopReason::BudgetReached)
            .map(|r| format!(" stop={}", r.as_str()))
            .unwrap_or_default();
        println!(
            "dataset={} n={} dim={} method={} cols={} error={:.3e} select_time={}{}",
            dataset_label(args),
            ds.n(),
            ds.dim(),
            method,
            approx.k(),
            err,
            fmt_secs(approx.selection_secs),
            stop_note,
        );
    }
}

fn cmd_approximate(args: &Args) -> i32 {
    let trace_out = trace_begin(args);
    let method = match Method::parse(&args.get_or("method", "oasis")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let spec = match run_spec(args, method, 450) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let run = resolve_or_exit("approximate", spec);
    // `approximate` always materializes the dataset (shard reads are the
    // parallel coordinator's mode), so the oracle always exists
    let ds: &Dataset = match run.dataset() {
        Ok(ds) => ds,
        Err(e) => {
            eprintln!("approximate: {e} (use `oasis parallel` for --shard-reads)");
            return 2;
        }
    };
    let slot = run.oracle_slot();
    let seed = run.method.seed;
    let mut stop: Option<StopReason> = None;
    // --trajectory FILE: collect one StepRecord per selection across
    // whichever session path runs (accel, native, or the fallback)
    let mut trajectory: Vec<oasis::sampling::StepRecord> = Vec::new();
    let record_trajectory = args.get("trajectory").is_some();

    let approx = if args.flag("accel") && method == Method::Oasis {
        let accel_run = Accel::try_default()
            .ok_or_else(|| {
                oasis::anyhow!("no artifacts found (run `make artifacts`)")
            })
            .and_then(|mut accel| {
                let mut s = run.open_accel_session(&mut accel, &slot)?;
                let reason = run_to_completion_observed(
                    s.as_mut(),
                    &run.stopping,
                    |r| trajectory.push(r),
                )?;
                Ok((s.snapshot()?, reason))
            });
        match accel_run {
            Ok((a, reason)) => {
                stop = Some(reason);
                a
            }
            Err(e) => {
                eprintln!("accel path failed ({e}); falling back to native");
                trajectory.clear(); // records from the failed attempt
                let native = (|| -> oasis::Result<NystromApprox> {
                    let mut s = run.open_session(&slot)?;
                    stop = Some(run_to_completion_observed(
                        s.as_mut(),
                        &run.stopping,
                        |r| trajectory.push(r),
                    )?);
                    s.snapshot()
                })();
                match native {
                    Ok(a) => a,
                    Err(e) => {
                        eprintln!("sampling failed: {e}");
                        return 1;
                    }
                }
            }
        }
    } else if method.has_session() {
        // stepwise methods run as sessions so --target-err and
        // --deadline-ms can stop them before the column budget — and
        // --resume-from warm-starts them from a stored artifact's Λ
        let result = (|| -> oasis::Result<NystromApprox> {
            let mut s = run.open_session(&slot)?;
            stop = Some(run_to_completion_observed(
                s.as_mut(),
                &run.stopping,
                |r| trajectory.push(r),
            )?);
            s.snapshot()
        })();
        match result {
            Ok(a) => a,
            Err(e) => {
                eprintln!("sampling failed: {e}");
                return 1;
            }
        }
    } else {
        // random | leverage | kmeans
        if record_trajectory {
            eprintln!(
                "--trajectory: method '{}' selects in one shot — no per-step \
                 trajectory to record",
                method.as_str()
            );
        }
        match run.one_shot(&slot) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("sampling failed: {e}");
                return 1;
            }
        }
    };

    if let Some(out) = args.get("trajectory") {
        if !trajectory.is_empty() {
            if let Err(e) = write_trajectory_csv(Path::new(out), &trajectory) {
                eprintln!("--trajectory {out}: {e}");
                return 1;
            }
            eprintln!(
                "wrote {} trajectory row(s) to {out}",
                trajectory.len()
            );
        }
    }

    let oracle = slot.get().expect("full dataset implies an oracle");
    let mode = args.get_or("error", if ds.n() <= 8000 { "full" } else { "sampled" });
    let err = if mode == "full" {
        relative_frobenius_error(oracle, &approx)
    } else {
        sampled_relative_error(oracle, &approx, 100_000, seed ^ 0xE44)
    };
    report_approximate(args, ds, method.as_str(), &approx, err, stop);
    if let Some(out) = args.get("save") {
        // selected points + resolved kernel ride along, so `oasis query
        // --load` can answer extensions without this dataset. Runs after
        // the report so the approximation moves into the artifact
        // instead of being cloned (C alone is n×k).
        let save = StoredArtifact::from_parts(
            approx,
            ds,
            &*run.kernel,
            Provenance {
                source: dataset_label(args),
                method: method.as_str().to_string(),
            },
            Some(err),
        )
        .map(|artifact| artifact.with_f32(args.flag("save-f32")))
        .and_then(|artifact| artifact.save(Path::new(out)));
        match save {
            // stderr so `--json` stdout stays a single parseable line
            Ok(bytes) => eprintln!("saved artifact to {out} ({bytes} bytes)"),
            Err(e) => {
                eprintln!("--save {out} failed: {e}");
                return 1;
            }
        }
    }
    trace_export(args, trace_out)
}

/// Serve extension queries from a stored artifact — no dataset, no
/// kernel oracle, just the file written by `approximate --save` or the
/// server's save endpoint.
fn cmd_query(args: &Args) -> i32 {
    let path = match args.get("load") {
        Some(p) => p,
        None => {
            eprintln!("query requires --load <artifact file>");
            return 2;
        }
    };
    let artifact = match StoredArtifact::load(Path::new(path)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("query: {e}");
            return 1;
        }
    };
    let points = match args.get("points").map(parse_points) {
        None => Vec::new(),
        Some(Ok(p)) => p,
        Some(Err(e)) => {
            eprintln!("--points: {e}");
            return 2;
        }
    };
    let targets = match args.get("targets").map(parse_indices) {
        None => Vec::new(),
        Some(Ok(t)) => t,
        Some(Err(e)) => {
            eprintln!("--targets: {e}");
            return 2;
        }
    };
    if points.is_empty() {
        // no query points: report what the artifact holds
        if args.flag("json") {
            println!("{}", artifact.summary_json());
        } else {
            println!(
                "artifact {path}: n={} k={} dim={} kernel={} method={} \
                 source={} error_estimate={}",
                artifact.n(),
                artifact.k(),
                artifact.dim(),
                artifact.kernel.name(),
                artifact.provenance.method,
                artifact.provenance.source,
                artifact
                    .error_estimate
                    .map(|e| format!("{e:.3e}"))
                    .unwrap_or_else(|| "n/a".into()),
            );
        }
        return 0;
    }
    let mut results = Vec::with_capacity(points.len());
    for (i, z) in points.iter().enumerate() {
        let w = match artifact.query_weights(z) {
            Ok(w) => w,
            Err(e) => {
                eprintln!("query point {i}: {e}");
                return 1;
            }
        };
        let vals = match artifact.extend(&w, &targets) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("query point {i}: {e}");
                return 1;
            }
        };
        results.push((w, vals));
    }
    if args.flag("json") {
        let arr: Vec<Json> = results
            .iter()
            .map(|(w, vals)| {
                let mut fields = vec![(
                    "weights",
                    Json::Arr(w.iter().map(|&x| Json::Num(x)).collect()),
                )];
                if !targets.is_empty() {
                    fields.push((
                        "kernel",
                        Json::Arr(vals.iter().map(|&x| Json::Num(x)).collect()),
                    ));
                }
                Json::obj(fields)
            })
            .collect();
        println!(
            "{}",
            Json::obj(vec![
                ("k", Json::Num(artifact.k() as f64)),
                ("results", Json::Arr(arr)),
            ])
        );
    } else {
        for (i, (w, vals)) in results.iter().enumerate() {
            if targets.is_empty() {
                let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
                println!("point {i}: weights k={} ‖w‖={norm:.6e}", w.len());
            } else {
                let rendered: Vec<String> = targets
                    .iter()
                    .zip(vals)
                    .map(|(t, v)| format!("g({t})={v:.6e}"))
                    .collect();
                println!("point {i}: {}", rendered.join(" "));
            }
        }
    }
    0
}

/// The task spec the `oasis task` flags describe.
fn task_spec(args: &Args) -> Result<TaskSpec, String> {
    let kind = TaskKind::parse(&args.get_or("task", "krr"))
        .map_err(|e| e.to_string())?;
    let mut spec = TaskSpec::new(kind);
    spec.ridge = args.f64_or("ridge", 1e-3);
    spec.clusters = args.usize_or("clusters", 2);
    spec.components =
        args.usize_or("components", kind.default_components(spec.clusters));
    spec.seed = args.u64_or("seed", 7);
    if let Some(p) = args.get("labels") {
        // "--label-col 0,2-4" fits one multi-output model over the
        // listed columns (same spelling the server's "label_cols" takes)
        let cols = LabelsSpec::parse_cols(&args.get_or("label-col", "0"))
            .map_err(|e| format!("--label-col: {e}"))?;
        spec.labels = Some(LabelsSpec {
            label: p.to_string(),
            path: PathBuf::from(p),
            cols,
        });
    }
    Ok(spec)
}

/// Report a fitted task and its predictions (JSON mirrors the server's
/// task responses, so the rendered `"predictions"` arrays are
/// byte-identical across front ends).
fn report_task(
    args: &Args,
    model: &FittedTask,
    cluster_sizes: Option<Vec<usize>>,
    predictions: Option<&oasis::tasks::TaskPrediction>,
) {
    if args.flag("json") {
        let mut fields = match model.summary_json() {
            Json::Obj(m) => m,
            _ => Default::default(),
        };
        if let Some(sizes) = cluster_sizes {
            fields.insert(
                "cluster_sizes".into(),
                Json::Arr(sizes.iter().map(|&s| Json::Num(s as f64)).collect()),
            );
        }
        if let Some(p) = predictions {
            fields.insert("predictions".into(), p.to_json());
        }
        println!("{}", Json::Obj(fields));
        return;
    }
    match model {
        FittedTask::Krr(m) => {
            let outputs = if m.outputs > 1 {
                format!(" outputs={}", m.outputs)
            } else {
                String::new()
            };
            println!(
                "task=krr k={}{} ridge={:e} train_rmse={:.6e}",
                m.k(),
                outputs,
                m.lambda,
                m.train_rmse
            )
        }
        FittedTask::Kpca(m) => {
            let vals: Vec<String> =
                m.vals.iter().map(|v| format!("{v:.4e}")).collect();
            println!(
                "task=kpca k={} components={} eigenvalues=[{}]",
                m.proj.rows,
                m.vals.len(),
                vals.join(", ")
            );
        }
        FittedTask::Cluster(m) => {
            let sizes = cluster_sizes
                .map(|s| format!(" sizes={s:?}"))
                .unwrap_or_default();
            println!(
                "task=cluster k={} clusters={} components={}{}",
                m.embedding.proj.rows,
                m.centroids.rows,
                m.embedding.vals.len(),
                sizes
            );
        }
    }
    match predictions {
        None => {}
        Some(oasis::tasks::TaskPrediction::Values(vs)) => {
            for (i, v) in vs.iter().enumerate() {
                println!("point {i}: f(z)={v:.6e}");
            }
        }
        Some(oasis::tasks::TaskPrediction::Matrix(rows)) => {
            for (i, r) in rows.iter().enumerate() {
                let vals: Vec<String> =
                    r.iter().map(|v| format!("{v:.6e}")).collect();
                println!("point {i}: f(z)=[{}]", vals.join(", "));
            }
        }
        Some(oasis::tasks::TaskPrediction::Embeddings(rows)) => {
            for (i, r) in rows.iter().enumerate() {
                let coords: Vec<String> =
                    r.iter().map(|c| format!("{c:.6e}")).collect();
                println!("point {i}: [{}]", coords.join(", "));
            }
        }
        Some(oasis::tasks::TaskPrediction::Labels { labels, .. }) => {
            for (i, l) in labels.iter().enumerate() {
                println!("point {i}: cluster {l}");
            }
        }
    }
}

/// Fit and run a downstream task — from a stored artifact (`--load`,
/// dataset-free) or a fresh approximation run (approximate's flags).
fn cmd_task(args: &Args) -> i32 {
    let trace_out = trace_begin(args);
    let spec = match task_spec(args) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("task: {e}");
            return 2;
        }
    };
    // query points to predict for, loaded like any dataset file
    let predict: Option<Vec<Vec<f64>>> = match args.get("predict") {
        None => None,
        Some(f) => {
            match oasis::data::load_dataset(Path::new(f), &LoadLimits::unlimited())
            {
                Ok(ds) => Some(
                    (0..ds.n()).map(|i| ds.point(i).to_vec()).collect(),
                ),
                Err(e) => {
                    eprintln!("task: --predict {f}: {e}");
                    return 2;
                }
            }
        }
    };

    let result = if let Some(art_path) = args.get("load") {
        task_from_artifact(args, &spec, art_path, predict.as_deref())
    } else {
        task_from_run(args, &spec, predict.as_deref())
    };
    match result {
        Ok(()) => trace_export(args, trace_out),
        Err(e) => {
            eprintln!("task: {e}");
            1
        }
    }
}

/// `oasis task --load ART`: fit (or reuse a stored model) from an
/// artifact — no dataset, no oracle.
fn task_from_artifact(
    args: &Args,
    spec: &TaskSpec,
    art_path: &str,
    predict: Option<&[Vec<f64>]>,
) -> oasis::Result<()> {
    let artifact = StoredArtifact::load(Path::new(art_path))?;
    // Without labels, a krr request reuses the model stored in the
    // artifact (the sample → save-with-task → predict pipeline);
    // kpca/cluster always fit fresh — they need no labels.
    let (model, cluster_sizes) = if spec.kind == TaskKind::Krr
        && spec.labels.is_none()
    {
        match &artifact.task {
            Some(m @ FittedTask::Krr(_)) => (m.clone(), None),
            _ => oasis::bail!(
                "krr needs --labels FILE (or an artifact saved with a fitted \
                 krr model via `oasis task --save`)"
            ),
        }
    } else {
        let cfg = SessionBuilder::new().resolve_task(spec)?;
        let fit = FittedTask::fit(&artifact.approx, &cfg)?;
        let sizes = fit
            .cluster_labels
            .as_ref()
            .map(|l| cluster_size_counts(l, spec.clusters));
        (fit.model, sizes)
    };
    let kernel = artifact.kernel.build();
    let predictions = match predict {
        None => None,
        Some(points) if args.flag("f32") => Some(model.predict_f32(
            &*kernel,
            &artifact.selected_points,
            points,
        )?),
        Some(points) => {
            Some(model.predict(&*kernel, &artifact.selected_points, points)?)
        }
    };
    report_task(args, &model, cluster_sizes, predictions.as_ref());
    if let Some(out) = args.get("save") {
        let mut tasked = artifact.with_task(model)?;
        if args.flag("save-f32") {
            // otherwise keep the loaded artifact's own encoding
            tasked = tasked.with_f32(true);
        }
        let bytes = tasked.save(Path::new(out))?;
        eprintln!("saved artifact with task model to {out} ({bytes} bytes)");
    }
    Ok(())
}

/// `oasis task` without `--load`: run a fresh approximation (same flags
/// as approximate) and fit on its final snapshot.
fn task_from_run(
    args: &Args,
    spec: &TaskSpec,
    predict: Option<&[Vec<f64>]>,
) -> oasis::Result<()> {
    let method = Method::parse(&args.get_or("method", "oasis"))?;
    // resolve the task config (and load the labels file) *before* the
    // potentially long sampling run — a typo'd labels path must fail
    // now, not after minutes of selection
    let cfg = SessionBuilder::new().resolve_task(spec)?;
    let rspec = run_spec(args, method, 450).map_err(oasis::error::Error::msg)?;
    let run = SessionBuilder::new().resolve(rspec)?;
    let ds = run.dataset()?.clone();
    let slot = run.oracle_slot();
    let approx = if method.has_session() {
        let mut s = run.open_session(&slot)?;
        run_to_completion(s.as_mut(), &run.stopping)?;
        s.snapshot()?
    } else {
        run.one_shot(&slot)?
    };
    if approx.indices.is_empty() {
        oasis::bail!(
            "method '{}' selects no data-point landmarks; tasks need a \
             column-sampling method",
            method.as_str()
        );
    }
    let fit = FittedTask::fit(&approx, &cfg)?;
    let sizes = fit
        .cluster_labels
        .as_ref()
        .map(|l| cluster_size_counts(l, spec.clusters));
    let selected = ds.select(&approx.indices);
    let predictions = match predict {
        None => None,
        Some(points) if args.flag("f32") => {
            Some(fit.model.predict_f32(&*run.kernel, &selected, points)?)
        }
        Some(points) => Some(fit.model.predict(&*run.kernel, &selected, points)?),
    };
    report_task(args, &fit.model, sizes, predictions.as_ref());
    if let Some(out) = args.get("save") {
        let artifact = StoredArtifact::from_parts(
            approx,
            &ds,
            &*run.kernel,
            Provenance {
                source: dataset_label(args),
                method: method.as_str().to_string(),
            },
            None,
        )?
        .with_f32(args.flag("save-f32"))
        .with_task(fit.model)?;
        let bytes = artifact.save(Path::new(out))?;
        eprintln!("saved artifact with task model to {out} ({bytes} bytes)");
    }
    Ok(())
}

fn cluster_size_counts(labels: &[usize], clusters: usize) -> Vec<usize> {
    let mut counts = vec![0usize; clusters];
    for &l in labels {
        if l < clusters {
            counts[l] += 1;
        }
    }
    counts
}

/// Parse `"x,y;x,y;…"` into query points.
fn parse_points(s: &str) -> Result<Vec<Vec<f64>>, String> {
    let mut out = Vec::new();
    for (i, part) in s.split(';').enumerate() {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let mut row = Vec::new();
        for field in part.split(',') {
            let x: f64 = field
                .trim()
                .parse()
                .map_err(|_| format!("point {i}: {field:?} is not a number"))?;
            // same rule as the server's query parser and the CSV loader
            if !x.is_finite() {
                return Err(format!("point {i}: {field:?} is not finite"));
            }
            row.push(x);
        }
        out.push(row);
    }
    if out.is_empty() {
        return Err("no points given".into());
    }
    Ok(out)
}

/// Parse `"0,5,11"` into row indices.
fn parse_indices(s: &str) -> Result<Vec<usize>, String> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| {
            t.parse::<usize>()
                .map_err(|_| format!("{t:?} is not an index"))
        })
        .collect()
}

fn cmd_parallel(args: &Args) -> i32 {
    if !log_begin("parallel", args) {
        return 2;
    }
    let trace_out = trace_begin(args);
    let spec = match run_spec(args, Method::OasisP, 500) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let run = resolve_or_exit("parallel", spec);
    let seed = run.method.seed;
    let result = (|| -> oasis::Result<_> {
        let mut session = match &run.method.listen {
            Some(addr) => {
                let transport = oasis::coordinator::TcpTransport::bind(addr)?;
                let bound = transport.local_addr()?;
                // stderr so `--json`-style stdout parsing stays clean;
                // printed *before* start blocks in the accept loop
                eprintln!(
                    "oASIS-P leader: waiting for {} worker(s) — start each \
                     with `oasis worker --join {bound}`",
                    run.method.workers,
                );
                run.open_oasis_p_with(Box::new(transport))?
            }
            None => run.open_oasis_p()?,
        };
        run_to_completion(&mut session, &run.stopping)?;
        // captured before finish_run consumes the session — the
        // shard-read report has no oracle to measure the error with,
        // and --save needs Λ's points without reloading the dataset
        let estimate = session.error_estimate();
        let selected = session.selected_points(0);
        let (approx, report) = session.finish_run()?;
        Ok((approx, report, estimate, selected))
    })();
    match result {
        Ok((approx, report, estimate, selected)) => {
            let slot = run.oracle_slot();
            match slot.get() {
                Some(oracle) => {
                    let err =
                        sampled_relative_error(oracle, &approx, 100_000, seed ^ 0xE44);
                    println!(
                        "oASIS-P n={} workers={} cols={} error={:.3e} wall={} [{}]",
                        run.n(),
                        report.workers,
                        approx.k(),
                        err,
                        fmt_secs(report.wall_secs),
                        report.metrics.summary(),
                    );
                }
                None => {
                    // --shard-reads: the leader never materialized the
                    // dataset, so report the distributed residual-trace
                    // estimate the workers piggybacked instead
                    let est = estimate
                        .map(|e| format!("{e:.3e}"))
                        .unwrap_or_else(|| "n/a".into());
                    println!(
                        "oASIS-P n={} workers={} cols={} error_est={} wall={} [{}]",
                        run.n(),
                        report.workers,
                        approx.k(),
                        est,
                        fmt_secs(report.wall_secs),
                        report.metrics.summary(),
                    );
                }
            }
            if let Some(out) = args.get("save") {
                let rows = selected.unwrap_or_default();
                let save = StoredArtifact::from_selected(
                    approx,
                    Dataset::from_rows(rows),
                    &*run.kernel,
                    Provenance {
                        source: dataset_label(args),
                        method: "oasis-p".to_string(),
                    },
                    estimate,
                )
                .map(|artifact| artifact.with_f32(args.flag("save-f32")))
                .and_then(|artifact| artifact.save(Path::new(out)));
                match save {
                    Ok(bytes) => {
                        eprintln!("saved artifact to {out} ({bytes} bytes)")
                    }
                    Err(e) => {
                        eprintln!("--save {out} failed: {e}");
                        return 1;
                    }
                }
            }
            trace_export_fleet(args, trace_out, report.worker_traces)
        }
        Err(e) => {
            eprintln!("oASIS-P failed: {e}");
            1
        }
    }
}

/// Join a TCP oASIS-P leader as one worker process: connect, receive the
/// shard assignment, read our own byte range of the dataset file, and
/// serve argmax/column requests until the leader sends Finish. Wire
/// protocol reference lives in the [`oasis::coordinator`] module docs.
fn cmd_worker(args: &Args) -> i32 {
    if !log_begin("worker", args) {
        return 2;
    }
    let Some(join) = args.get("join") else {
        eprintln!(
            "worker: --join HOST:PORT is required (the address the leader's \
             `oasis parallel --listen` printed)"
        );
        return 2;
    };
    let opts = oasis::coordinator::WorkerRunOpts {
        data_override: args.get("data").map(PathBuf::from),
        throttle: {
            let ms = args.u64_or("throttle-ms", 0);
            (ms > 0).then(|| std::time::Duration::from_millis(ms))
        },
        trace_file: args.get("trace").map(PathBuf::from),
    };
    match oasis::coordinator::run_worker(join, opts) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("worker: {e}");
            1
        }
    }
}

/// Write a dataset (generator, or an existing CSV converted) as an
/// oasis-matrix binary file — the header+checksum format whose byte
/// ranges `parallel --shard-reads` workers seek into.
fn cmd_export(args: &Args) -> i32 {
    let Some(out) = args.get("out") else {
        eprintln!("export: --out FILE is required");
        return 2;
    };
    let ds = match dataset_spec(args).build(&LoadLimits::unlimited()) {
        Ok(ds) => ds,
        Err(e) => {
            eprintln!("export: {e}");
            return 2;
        }
    };
    match oasis::data::save_matrix(Path::new(out), &ds) {
        Ok(bytes) => {
            println!(
                "wrote {} points (dim {}) to {out} ({bytes} bytes)",
                ds.n(),
                ds.dim()
            );
            0
        }
        Err(e) => {
            eprintln!("export: {e}");
            1
        }
    }
}

fn cmd_seed(args: &Args) -> i32 {
    use oasis::seed::{css_projection_error, Seed, SeedConfig};
    let ds = match dataset_spec(args).build(&LoadLimits::unlimited()) {
        Ok(ds) => ds,
        Err(e) => {
            eprintln!("seed: {e}");
            return 2;
        }
    };
    let cfg = SeedConfig {
        dict_size: args.usize_or("dict", 50).min(ds.n()),
        sparsity: args.usize_or("sparsity", 5),
        tol_sq: 1e-12,
        seed: args.u64_or("seed", 7),
    };
    match Seed::decompose(&ds, &cfg) {
        Ok(seed) => {
            println!(
                "SEED: n={} dict={} sparsity≤{} reconstruction={:.3e} eq7={:.3e}",
                ds.n(),
                seed.dictionary.len(),
                cfg.sparsity,
                seed.relative_error,
                css_projection_error(&ds, &seed.dictionary),
            );
            if let Some(kc) = args.get("clusters") {
                let k: usize = kc.parse().unwrap_or(2);
                let labels =
                    oasis::seed::spectral_cluster(&seed.affinity(), k, cfg.seed);
                let mut counts = vec![0usize; k];
                for &l in &labels {
                    counts[l] += 1;
                }
                println!("cluster sizes: {counts:?}");
            }
            0
        }
        Err(e) => {
            eprintln!("SEED failed: {e}");
            1
        }
    }
}

/// Host the approximation server. Prints one "listening" line (with the
/// resolved port — useful with `--port 0`) and serves until
/// `POST /shutdown`.
fn cmd_serve(args: &Args) -> i32 {
    if !log_begin("serve", args) {
        return 2;
    }
    let host = args.get_or("host", "127.0.0.1");
    let port = args.usize_or("port", 7437);
    if port > u16::MAX as usize {
        eprintln!("--port must be ≤ {}", u16::MAX);
        return 2;
    }
    let fs_root = std::path::PathBuf::from(args.get_or("fs-root", "."));
    if !fs_root.is_dir() {
        eprintln!("serve: --fs-root {} is not a directory", fs_root.display());
        return 2;
    }
    let config = oasis::server::ServerConfig {
        fs_root,
        threads: args.usize_or("threads", 0),
        queue: args.usize_or("queue", 128),
        max_rps: args.u64_or("max-rps", 0),
        max_rps_per_ip: args.u64_or("max-rps-per-ip", 0),
        drain: std::time::Duration::from_millis(args.u64_or("drain-ms", 5000)),
    };
    let server =
        match oasis::server::Server::bind_with(&format!("{host}:{port}"), config) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("serve: could not bind {host}:{port}: {e}");
                return 1;
            }
        };
    match server.local_addr() {
        Ok(addr) => println!("oasis serve listening on http://{addr}"),
        Err(e) => {
            eprintln!("serve: no local address: {e}");
            return 1;
        }
    }
    match server.run() {
        Ok(()) => {
            println!("oasis serve stopped");
            0
        }
        Err(e) => {
            eprintln!("serve failed: {e}");
            1
        }
    }
}

/// One batch-size sweep point of the serving benchmark.
struct BenchPoint {
    batch: usize,
    requests: usize,
    errors: usize,
    wall_secs: f64,
    hist: oasis::obs::Hist,
}

impl BenchPoint {
    fn rps(&self) -> f64 {
        if self.wall_secs > 0.0 {
            (self.requests - self.errors) as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    fn predictions_per_sec(&self) -> f64 {
        self.rps() * self.batch as f64
    }

    fn to_json(&self) -> Json {
        let ms = 1e3;
        Json::obj(vec![
            ("batch", Json::Num(self.batch as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("rps", Json::Num(self.rps())),
            ("predictions_per_sec", Json::Num(self.predictions_per_sec())),
            ("mean_ms", Json::Num(self.hist.mean() * ms)),
            ("p50_ms", Json::Num(self.hist.quantile(0.5) * ms)),
            ("p99_ms", Json::Num(self.hist.quantile(0.99) * ms)),
        ])
    }
}

/// Load-generate KRR predict traffic against a serve instance and
/// report p50/p99 latency and requests/sec across predict batch sizes —
/// the "is batching worth it" trajectory (one request carrying B points
/// is served as one B×k kernel block + one blocked product, where B
/// single-point requests pay B full HTTP+dispatch+kernel round trips).
///
/// With `--port` it drives an already-running server; without, it binds
/// an in-process server on an ephemeral port (honoring `--threads`) so
/// CI needs no process choreography. Setup is self-contained: create a
/// session, grow it, fit a krr model once with inline labels, then
/// sweep label-free predict-only requests (the fit-once-predict-many
/// serve pattern) over `--conns` keep-alive connections.
fn cmd_bench_serve(args: &Args) -> i32 {
    use oasis::server::http::ClientConn;
    let quick = args.flag("quick");
    let conns = args.usize_or("conns", if quick { 4 } else { 8 }).max(1);
    let requests = args
        .usize_or("requests", if quick { 240 } else { 2000 })
        .max(conns);
    let batches = match parse_indices(&args.get_or("batches", "1,16,64")) {
        Ok(b) if !b.is_empty() && b.iter().all(|&x| x >= 1) => b,
        _ => {
            eprintln!("bench-serve: --batches expects sizes ≥ 1, e.g. \"1,16,64\"");
            return 2;
        }
    };
    let f32_mode = args.flag("f32");
    let n = 512usize;
    let session = "bench-serve";

    // target server: external (--port) or self-hosted on an ephemeral port
    let mut local: Option<(
        std::sync::Arc<oasis::server::ServerState>,
        std::thread::JoinHandle<oasis::Result<()>>,
    )> = None;
    let addr = if args.get("port").is_some() {
        use std::net::ToSocketAddrs;
        let host = args.get_or("host", "127.0.0.1");
        let port = args.usize_or("port", 7437);
        match format!("{host}:{port}").to_socket_addrs().ok().and_then(|mut a| a.next())
        {
            Some(a) => a,
            None => {
                eprintln!("bench-serve: cannot resolve {host}:{port}");
                return 2;
            }
        }
    } else {
        let config = oasis::server::ServerConfig {
            threads: args.usize_or("threads", 0),
            ..Default::default()
        };
        let server =
            match oasis::server::Server::bind_with("127.0.0.1:0", config) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("bench-serve: could not bind a local server: {e}");
                    return 1;
                }
            };
        let addr = match server.local_addr() {
            Ok(a) => a,
            Err(e) => {
                eprintln!("bench-serve: no local address: {e}");
                return 1;
            }
        };
        let state = server.state();
        local = Some((state, std::thread::spawn(move || server.run())));
        addr
    };

    let finish = |local: Option<(
        std::sync::Arc<oasis::server::ServerState>,
        std::thread::JoinHandle<oasis::Result<()>>,
    )>| {
        if let Some((state, join)) = local {
            state.request_stop();
            let _ = join.join();
        }
    };

    let result = (|| -> oasis::Result<Vec<BenchPoint>> {
        let mut c = ClientConn::connect(addr)?;
        // a leftover session from an aborted run would 409 the create
        let _ = c.request("DELETE", &format!("/sessions/{session}"), "");
        let create = format!(
            "{{\"name\":\"{session}\",\"dataset\":{{\"generator\":\"two-moons\",\
             \"n\":{n},\"seed\":7}},\"max_cols\":48,\"init_cols\":8}}"
        );
        let (status, body) = c.request("POST", "/sessions", &create)?;
        if status != 200 {
            oasis::bail!("create failed: HTTP {status}: {body}");
        }
        let (status, body) = c.request(
            "POST",
            &format!("/sessions/{session}/step"),
            "{\"steps\":40}",
        )?;
        if status != 200 {
            oasis::bail!("step failed: HTTP {status}: {body}");
        }
        // fit once with inline labels; the sweep's label-free requests
        // then reuse the cached fitted model (the serve pattern)
        let labels: Vec<String> =
            (0..n).map(|i| format!("{}", (i % 2) as f64)).collect();
        let fit = format!(
            "{{\"task\":\"krr\",\"ridge\":1e-3,\"labels\":[{}]}}",
            labels.join(",")
        );
        let (status, body) =
            c.request("POST", &format!("/sessions/{session}/task"), &fit)?;
        if status != 200 {
            oasis::bail!("krr fit failed: HTTP {status}: {body}");
        }

        // deterministic query points over the two-moons bounding box
        let mut rng = oasis::util::rng::Pcg64::new(42);
        let pool: Vec<(f64, f64)> = (0..256)
            .map(|_| (rng.f64() * 4.0 - 1.5, rng.f64() * 2.5 - 1.0))
            .collect();
        let path = format!("/sessions/{session}/task");
        let mut points_out = Vec::new();
        for &batch in &batches {
            // a few distinct bodies per batch size, cycled per request,
            // so response caching cannot trivialize the measurement
            let bodies: Vec<String> = (0..16)
                .map(|v| {
                    let pts: Vec<String> = (0..batch)
                        .map(|j| {
                            let (x, y) = pool[(v * 37 + j) % pool.len()];
                            format!("[{x},{y}]")
                        })
                        .collect();
                    let f32_field = if f32_mode { ",\"f32\":true" } else { "" };
                    format!("{{\"predict\":[{}]{f32_field}}}", pts.join(","))
                })
                .collect();
            let per_thread = requests.div_ceil(conns);
            let total = per_thread * conns;
            let t0 = std::time::Instant::now();
            let thread_results: Vec<(Vec<f64>, usize)> =
                std::thread::scope(|s| {
                    let handles: Vec<_> = (0..conns)
                        .map(|t| {
                            let bodies = &bodies;
                            let path = &path;
                            s.spawn(move || {
                                let mut lats =
                                    Vec::with_capacity(per_thread);
                                let mut errors = 0usize;
                                let mut conn = match ClientConn::connect(addr)
                                {
                                    Ok(c) => c,
                                    Err(_) => return (lats, per_thread),
                                };
                                for i in 0..per_thread {
                                    let body =
                                        &bodies[(t + i) % bodies.len()];
                                    let r0 = std::time::Instant::now();
                                    match conn.request("POST", path, body) {
                                        Ok((200, _)) => lats.push(
                                            r0.elapsed().as_secs_f64(),
                                        ),
                                        _ => errors += 1,
                                    }
                                }
                                (lats, errors)
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().unwrap_or((Vec::new(), per_thread)))
                        .collect()
                });
            let wall_secs = t0.elapsed().as_secs_f64();
            let mut hist = oasis::obs::Hist::latency();
            let mut errors = 0usize;
            for (lats, errs) in thread_results {
                errors += errs;
                for l in lats {
                    hist.record(l);
                }
            }
            points_out.push(BenchPoint {
                batch,
                requests: total,
                errors,
                wall_secs,
                hist,
            });
        }
        let _ = c.request("DELETE", &format!("/sessions/{session}"), "");
        Ok(points_out)
    })();

    let points = match result {
        Ok(p) => p,
        Err(e) => {
            eprintln!("bench-serve: {e}");
            finish(local);
            return 1;
        }
    };
    finish(local);

    let single_pps = points
        .iter()
        .find(|p| p.batch == 1)
        .map(BenchPoint::predictions_per_sec);
    let best_batched = points
        .iter()
        .filter(|p| p.batch >= 16)
        .map(|p| p.predictions_per_sec())
        .fold(f64::NAN, f64::max);
    let speedup = match single_pps {
        Some(s) if s > 0.0 && best_batched.is_finite() => {
            Some(best_batched / s)
        }
        _ => None,
    };

    let results_json: Vec<Json> = points.iter().map(BenchPoint::to_json).collect();
    let mut serve_fields = vec![
        ("conns", Json::Num(conns as f64)),
        ("requests_per_batch", Json::Num(requests as f64)),
        ("f32", Json::Bool(f32_mode)),
        ("results", Json::Arr(results_json)),
    ];
    if let Some(s) = speedup {
        serve_fields.push(("batched_speedup_points_per_sec", Json::Num(s)));
    }
    let serve_json = Json::obj(serve_fields);

    if args.flag("json") {
        println!("{serve_json}");
    } else {
        for p in &points {
            println!(
                "batch={:<4} requests={:<6} errors={:<3} rps={:<10.1} \
                 predictions/s={:<12.1} p50={:.3}ms p99={:.3}ms",
                p.batch,
                p.requests,
                p.errors,
                p.rps(),
                p.predictions_per_sec(),
                p.hist.quantile(0.5) * 1e3,
                p.hist.quantile(0.99) * 1e3,
            );
        }
        if let Some(s) = speedup {
            println!(
                "batched predict serves {s:.1}× the single-point \
                 predictions/sec"
            );
        }
    }
    if points.iter().any(|p| p.errors > 0) {
        eprintln!("bench-serve: some requests failed (see errors column)");
        return 1;
    }

    if let Some(out) = args.get("out") {
        let existing = std::fs::read_to_string(out)
            .ok()
            .and_then(|t| Json::parse(&t).ok());
        let mut obj = match existing {
            Some(Json::Obj(m)) => m,
            _ => Default::default(),
        };
        obj.insert("serve".into(), serve_json);
        let rendered = Json::Obj(obj).to_string();
        if let Err(e) =
            oasis::util::fsio::write_atomic(Path::new(out), rendered.as_bytes())
        {
            eprintln!("bench-serve: --out {out}: {e}");
            return 1;
        }
        eprintln!("merged \"serve\" section into {out}");
    }
    0
}

fn cmd_info() -> i32 {
    match Manifest::load(&Manifest::default_dir()) {
        Ok(m) => {
            println!("artifacts ({}):", m.artifacts.len());
            for a in &m.artifacts {
                println!(
                    "  {:30} op={:18} dims={:?}",
                    a.name, a.op, a.dims
                );
            }
        }
        Err(e) => println!("no artifact manifest: {e}"),
    }
    match oasis::runtime::Executor::cpu() {
        Ok(ex) => println!("PJRT platform: {}", ex.platform()),
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    0
}
