//! Crate error type — a minimal, dependency-free `anyhow` substitute.
//!
//! Provides the three pieces of the `anyhow` API the crate uses:
//! [`Error`] (an opaque, `Display`-able error value), the
//! [`anyhow!`](crate::anyhow)/[`bail!`](crate::bail) macros, and the
//! [`Context`] extension trait. Any `std::error::Error` converts into
//! [`Error`] via `?`, so library code keeps ordinary error-propagation
//! ergonomics without pulling a registry dependency into the offline
//! tier-1 build.

use std::fmt;

/// An opaque error: a message plus an optional source it was built from.
///
/// Like `anyhow::Error`, this type deliberately does **not** implement
/// `std::error::Error` — that keeps the blanket `From<E: std::error::Error>`
/// conversion coherent (no overlap with the reflexive `From<Error>`).
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from anything printable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Prefix the message with additional context (innermost last).
    pub fn wrap(self, context: impl fmt::Display) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut src = self.source.as_deref().and_then(|e| e.source());
        while let Some(e) = src {
            write!(f, "\n  caused by: {e}")?;
            src = e.source();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// Construct an [`Error`] from a format string: `anyhow!("bad k = {k}")`.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`]: `bail!("workers must be ≥ 1")`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `anyhow::Context`-style extension: attach a message to the error arm.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> crate::Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> crate::Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> crate::Result<T> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> crate::Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> crate::Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> crate::Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> crate::Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(!format!("{err}").is_empty());
    }

    #[test]
    fn macros_and_context() {
        let e = anyhow!("bad value {}", 3);
        assert_eq!(format!("{e}"), "bad value 3");
        let r: crate::Result<()> = Err(e).context("while parsing");
        let msg = format!("{}", r.unwrap_err());
        assert_eq!(msg, "while parsing: bad value 3");
        let o: Option<u32> = None;
        assert!(o.with_context(|| "missing").is_err());
    }

    #[test]
    fn bail_returns_early() {
        fn f(x: i32) -> crate::Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(format!("{}", f(-1).unwrap_err()).contains("negative"));
    }
}
