//! Log₂-bucketed histograms with quantile estimation.
//!
//! A [`Hist`] holds a fixed array of power-of-two buckets over a
//! configurable base unit: bucket 0 covers `[0, base)` and bucket `i ≥ 1`
//! covers `[base·2^(i-1), base·2^i)`, so 44 buckets span 1 µs to ~200
//! days for latencies (or 1 byte to 8 TiB for sizes) in 360 bytes of
//! state with O(1) recording. Quantiles are estimated by walking the
//! cumulative counts to the target rank and interpolating linearly
//! inside the landing bucket — the estimate is exact at bucket edges and
//! off by at most the bucket width (a factor of 2 relative) in the
//! worst case, far tighter in practice.
//!
//! The same shape renders three ways: `to_json()` for the server's JSON
//! stats (count/mean/last/max plus p50/p90/p99, all in ms),
//! [`Hist::cumulative_buckets`] for Prometheus `_bucket` series, and
//! [`Hist::quantile`] wherever a single number is wanted.

use crate::util::json::Json;

/// Bucket count: base·2^42 at the top — 1 µs base reaches ~50 days,
/// 1 byte base reaches 4 TiB. Values past the top land in the last
/// bucket (quantile estimates clamp to the observed max).
const BUCKETS: usize = 44;

/// A log₂-bucketed histogram. `Clone` and plain-field so it can live
/// inside mutex-guarded stats structs; wrap it in a `Mutex` to share.
#[derive(Clone, Debug)]
pub struct Hist {
    counts: [u64; BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    last: f64,
    base: f64,
}

impl Default for Hist {
    /// The latency shape (seconds, 1 µs base).
    fn default() -> Self {
        Hist::latency()
    }
}

impl Hist {
    /// A histogram over seconds with a 1 µs finest bucket.
    pub fn latency() -> Hist {
        Hist::with_base(1e-6)
    }

    /// A histogram over byte counts with a 1-byte finest bucket.
    pub fn bytes() -> Hist {
        Hist::with_base(1.0)
    }

    /// A histogram whose bucket 0 covers `[0, base)`.
    pub fn with_base(base: f64) -> Hist {
        assert!(base.is_finite() && base > 0.0, "Hist base must be > 0");
        Hist {
            counts: [0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            last: 0.0,
            base,
        }
    }

    fn bucket_of(&self, v: f64) -> usize {
        if v < self.base {
            return 0;
        }
        // floor(log2(v/base)) + 1, clamped to the top bucket
        let exp = (v / self.base).log2().floor();
        ((exp as usize).saturating_add(1)).min(BUCKETS - 1)
    }

    /// Inclusive upper bound of bucket `i` (`base·2^i`; bucket 0 is
    /// `[0, base)`).
    fn upper(&self, i: usize) -> f64 {
        self.base * (i as f64).exp2()
    }

    /// Record one observation. Non-finite values are skipped (a NaN
    /// measurement must never poison the stats — see the matching
    /// `total_cmp` rule in `util::timing::Summary`); negatives clamp
    /// to 0.
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let v = v.max(0.0);
        self.counts[self.bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.last = v;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn last(&self) -> f64 {
        self.last
    }

    /// Estimate the `q`-quantile (`0 ≤ q ≤ 1`) by cumulative-count
    /// bucket walk + linear interpolation inside the landing bucket.
    /// NaN when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = (q.clamp(0.0, 1.0)) * self.count as f64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = cum + c;
            if (next as f64) >= rank {
                let lo = if i == 0 { 0.0 } else { self.upper(i - 1) };
                let hi = self.upper(i);
                let frac = (rank - cum as f64) / c as f64;
                let est = lo + frac.clamp(0.0, 1.0) * (hi - lo);
                // the observed extremes bound the estimate tighter than
                // the bucket edges (and cap the open-ended top bucket)
                return est.clamp(self.min, self.max);
            }
            cum = next;
        }
        self.max
    }

    /// Cumulative `(upper_bound, count ≤ bound)` pairs for Prometheus
    /// `_bucket` series: every bucket up to the highest non-empty one
    /// (at least bucket 0), finite bounds only — the caller appends the
    /// `+Inf` bucket, which by construction equals [`Hist::count`].
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let top = self.counts.iter().rposition(|&c| c > 0).unwrap_or(0);
        let mut cum = 0u64;
        (0..=top)
            .map(|i| {
                cum += self.counts[i];
                (self.upper(i), cum)
            })
            .collect()
    }

    /// The JSON rendering for latency histograms (milliseconds), a
    /// superset of the old mean/max-only `LatencyStats` fields:
    /// `{count, mean_ms, last_ms, max_ms, p50_ms, p90_ms, p99_ms}`.
    pub fn to_json(&self) -> Json {
        let ms = 1e3;
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("mean_ms", Json::Num(self.mean() * ms)),
            ("last_ms", Json::Num(self.last * ms)),
            ("max_ms", Json::Num(self.max() * ms)),
            ("p50_ms", Json::Num(self.quantile(0.50) * ms)),
            ("p90_ms", Json::Num(self.quantile(0.90) * ms)),
            ("p99_ms", Json::Num(self.quantile(0.99) * ms)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// The exact percentile of a sorted sample (nearest-rank).
    fn exact_quantile(sorted: &[f64], q: f64) -> f64 {
        let idx = ((q * sorted.len() as f64).ceil() as usize)
            .clamp(1, sorted.len())
            - 1;
        sorted[idx]
    }

    /// Quantile estimates must land within one log₂ bucket (×2 relative
    /// error) of the exact percentile — and the interpolation usually
    /// does far better. Checked on uniform and heavy-tailed synthetic
    /// distributions.
    #[test]
    fn quantiles_track_exact_percentiles() {
        let mut rng = Pcg64::new(11);
        for dist in 0..2 {
            let mut h = Hist::latency();
            let mut xs: Vec<f64> = (0..20_000)
                .map(|_| {
                    let u = rng.f64();
                    if dist == 0 {
                        // uniform over [0, 100ms)
                        0.1 * u
                    } else {
                        // heavy-tailed: exponential-ish over µs..s
                        1e-6 * (u * 20.0).exp2()
                    }
                })
                .collect();
            for &x in &xs {
                h.record(x);
            }
            xs.sort_by(|a, b| a.total_cmp(b));
            for q in [0.50, 0.90, 0.99] {
                let exact = exact_quantile(&xs, q);
                let est = h.quantile(q);
                assert!(
                    est >= exact / 2.0 && est <= exact * 2.0,
                    "dist {dist} p{q}: est {est:.3e} vs exact {exact:.3e}"
                );
            }
        }
    }

    #[test]
    fn records_edges_and_ignores_nonfinite() {
        let mut h = Hist::latency();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 0);
        assert!(h.quantile(0.5).is_nan());
        h.record(-1.0); // clamps to 0
        h.record(0.0);
        h.record(1e9); // past the top bucket: clamps, never panics
        assert_eq!(h.count(), 3);
        assert_eq!(h.max(), 1e9);
        assert!(h.quantile(1.0) <= 1e9);
        let buckets = h.cumulative_buckets();
        assert_eq!(buckets.last().unwrap().1, 3, "top cumulative = count");
    }

    #[test]
    fn cumulative_buckets_are_monotone() {
        let mut rng = Pcg64::new(3);
        let mut h = Hist::bytes();
        for _ in 0..5000 {
            h.record((rng.f64() * 1e6).floor());
        }
        let buckets = h.cumulative_buckets();
        for w in buckets.windows(2) {
            assert!(w[1].0 > w[0].0, "bounds strictly increase");
            assert!(w[1].1 >= w[0].1, "cumulative counts never decrease");
        }
        assert_eq!(buckets.last().unwrap().1, h.count());
    }

    #[test]
    fn json_keeps_latencystats_fields_and_adds_quantiles() {
        let mut h = Hist::latency();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        let j = h.to_json();
        assert_eq!(j.get("count").and_then(Json::as_f64), Some(100.0));
        for key in ["mean_ms", "last_ms", "max_ms", "p50_ms", "p90_ms", "p99_ms"]
        {
            assert!(
                j.get(key).and_then(Json::as_f64).is_some(),
                "missing {key}"
            );
        }
        let p50 = j.get("p50_ms").and_then(Json::as_f64).unwrap();
        let p99 = j.get("p99_ms").and_then(Json::as_f64).unwrap();
        assert!(p50 <= p99);
    }
}
