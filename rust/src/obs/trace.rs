//! Process-global span/event recorder for hot-path tracing.
//!
//! Off by default: every instrumentation point costs one relaxed atomic
//! load until [`enable`] is called, so the guards stay in the sampling,
//! coordinator, engine, and task hot paths unconditionally. When
//! enabled, [`span`] guards record *complete* events (start + duration,
//! monotonic µs since [`enable`]) into a bounded ring buffer — when the
//! buffer fills, the **oldest** events are dropped and counted, so a
//! long run keeps its most recent window and the export says exactly
//! how much is missing.
//!
//! Nesting is tracked per thread (a thread-local depth counter — the
//! span stack), so exports preserve parent/child structure: Chrome's
//! trace viewer nests complete events on the same thread row by
//! timestamp containment, and the JSONL export carries an explicit
//! `depth` field.
//!
//! Two export shapes, both built on [`drain`]:
//! * [`Trace::to_chrome_json`] — the Chrome `trace_event` format
//!   (`chrome://tracing`, <https://ui.perfetto.dev>).
//! * [`Trace::to_jsonl`] — one JSON object per line, grep-friendly.
//!
//! [`Trace::phase_summary`] aggregates the spans per name into
//! [`Hist`]s — the CLI's per-phase timing table.
//!
//! For the oASIS-P fleet, each `oasis worker` process records into its
//! own ring and ships [`OwnedEvent`]s leader-ward over the wire; the
//! leader merges its drain plus every worker's chunks into
//! [`TraceTrack`]s and renders them with [`merged_chrome_json`] — one
//! Chrome timeline with a distinct `pid` row per process.

use super::hist::Hist;
use crate::util::json::Json;
use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Default ring capacity: at ~56 bytes/event this is ~3.7 MiB, enough
/// for a 450-column selection's every phase with plenty of headroom.
pub const DEFAULT_CAPACITY: usize = 1 << 16;

/// One recorded event. `dur_us == 0` with a `value` is a counter
/// sample (e.g. per-frame wire bytes); otherwise a completed span.
#[derive(Clone, Debug)]
pub struct Event {
    pub name: &'static str,
    /// Category: the subsystem that emitted it (`sampling`, `coord`,
    /// `engine`, `tasks`, `net`, `server`).
    pub cat: &'static str,
    /// Start, µs since the recorder was enabled (monotonic).
    pub ts_us: u64,
    /// Span duration in µs (0 for counter events).
    pub dur_us: u64,
    /// Recorder-assigned thread id (dense, starts at 1).
    pub tid: u64,
    /// Nesting depth on its thread at record time (0 = top level).
    pub depth: u32,
    /// Counter payload (wire bytes, batch sizes, …).
    pub value: Option<f64>,
}

/// An owned mirror of [`Event`] whose name/category are `String`s, so
/// worker processes can ship recorded events over the wire (an
/// [`Event`]'s `&'static str` fields cannot cross a process boundary).
#[derive(Clone, Debug, PartialEq)]
pub struct OwnedEvent {
    pub name: String,
    pub cat: String,
    pub ts_us: u64,
    pub dur_us: u64,
    pub tid: u64,
    pub depth: u32,
    pub value: Option<f64>,
}

impl Event {
    /// Owned copy for wire shipping.
    pub fn to_owned_event(&self) -> OwnedEvent {
        OwnedEvent {
            name: self.name.to_string(),
            cat: self.cat.to_string(),
            ts_us: self.ts_us,
            dur_us: self.dur_us,
            tid: self.tid,
            depth: self.depth,
            value: self.value,
        }
    }
}

/// One process's worth of events in a merged fleet trace. `pid` becomes
/// the Chrome process row; `label` its `process_name` metadata.
#[derive(Clone, Debug, Default)]
pub struct TraceTrack {
    pub pid: u64,
    pub label: String,
    pub events: Vec<OwnedEvent>,
    /// Events that process's bounded ring discarded before shipping.
    pub dropped: u64,
}

fn owned_event_json(e: &OwnedEvent, pid: u64) -> Json {
    let mut fields = vec![
        ("name", Json::Str(e.name.clone())),
        ("cat", Json::Str(e.cat.clone())),
        (
            "ph",
            Json::Str(if e.value.is_some() { "C" } else { "X" }.to_string()),
        ),
        ("ts", Json::Num(e.ts_us as f64)),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(e.tid as f64)),
    ];
    match e.value {
        Some(v) => {
            fields.push(("args", Json::obj(vec![("value", Json::Num(v))])))
        }
        None => fields.push(("dur", Json::Num(e.dur_us as f64))),
    }
    Json::obj(fields)
}

/// Merge per-process tracks into one Chrome `trace_event` JSON. Each
/// track renders on its own `pid` row, named via a `process_name`
/// metadata event, so the whole fleet reads as one timeline.
pub fn merged_chrome_json(tracks: &[TraceTrack]) -> Json {
    let mut events: Vec<Json> = Vec::new();
    let mut dropped = 0u64;
    for track in tracks {
        events.push(Json::obj(vec![
            ("name", Json::Str("process_name".to_string())),
            ("ph", Json::Str("M".to_string())),
            ("pid", Json::Num(track.pid as f64)),
            ("tid", Json::Num(0.0)),
            (
                "args",
                Json::obj(vec![("name", Json::Str(track.label.clone()))]),
            ),
        ]));
        for e in &track.events {
            events.push(owned_event_json(e, track.pid));
        }
        dropped += track.dropped;
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
        ("droppedEvents", Json::Num(dropped as f64)),
    ])
}

/// Merged tracks as JSON lines (one event object per line, with the
/// track `pid` and `label` attached) — grep/jq-friendly.
pub fn merged_jsonl(tracks: &[TraceTrack]) -> String {
    let mut out = String::new();
    for track in tracks {
        for e in &track.events {
            let mut fields = vec![
                ("name", Json::Str(e.name.clone())),
                ("cat", Json::Str(e.cat.clone())),
                ("pid", Json::Num(track.pid as f64)),
                ("process", Json::Str(track.label.clone())),
                ("ts_us", Json::Num(e.ts_us as f64)),
                ("dur_us", Json::Num(e.dur_us as f64)),
                ("tid", Json::Num(e.tid as f64)),
                ("depth", Json::Num(e.depth as f64)),
            ];
            if let Some(v) = e.value {
                fields.push(("value", Json::Num(v)));
            }
            out.push_str(&Json::obj(fields).to_string());
            out.push('\n');
        }
    }
    out
}

struct Ring {
    events: VecDeque<Event>,
    capacity: usize,
    dropped: u64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static RING: Mutex<Option<Ring>> = Mutex::new(None);
static ORIGIN: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    static DEPTH: Cell<u32> = const { Cell::new(0) };
}

fn origin() -> Instant {
    *ORIGIN.get_or_init(Instant::now)
}

/// Is the recorder live? One relaxed load — the entire disabled-path
/// cost of an instrumentation point.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Start recording with the default ring capacity. Clears any
/// previously recorded events.
pub fn enable() {
    enable_with_capacity(DEFAULT_CAPACITY);
}

/// Start recording into a ring of `capacity` events (≥ 1). Clears any
/// previously recorded events and resets the dropped counter.
pub fn enable_with_capacity(capacity: usize) {
    let capacity = capacity.max(1);
    origin(); // pin the monotonic zero before the first event
    let mut ring = RING.lock().unwrap_or_else(|e| e.into_inner());
    *ring = Some(Ring { events: VecDeque::new(), capacity, dropped: 0 });
    ENABLED.store(true, Ordering::Relaxed);
}

/// Stop recording. Events already in the ring stay until [`drain`].
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Take everything recorded so far (and the count of events the ring
/// dropped), leaving an empty ring. The recorder stays in its current
/// enabled/disabled state.
pub fn drain() -> Trace {
    let mut ring = RING.lock().unwrap_or_else(|e| e.into_inner());
    match ring.as_mut() {
        None => Trace { events: Vec::new(), dropped: 0 },
        Some(r) => {
            let events = std::mem::take(&mut r.events).into();
            let dropped = std::mem::replace(&mut r.dropped, 0);
            Trace { events, dropped }
        }
    }
}

fn push(ev: Event) {
    let mut ring = RING.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(r) = ring.as_mut() {
        if r.events.len() == r.capacity {
            r.events.pop_front();
            r.dropped += 1;
        }
        r.events.push_back(ev);
    }
}

/// Record a counter event (a point-in-time value, e.g. the byte size
/// of one wire frame). No-op while disabled.
#[inline]
pub fn event(name: &'static str, cat: &'static str, value: f64) {
    if !enabled() {
        return;
    }
    push(Event {
        name,
        cat,
        ts_us: origin().elapsed().as_micros() as u64,
        dur_us: 0,
        tid: TID.with(|t| *t),
        depth: DEPTH.with(|d| d.get()),
        value: Some(value),
    });
}

/// Open a span; the returned guard records a complete event when it
/// drops. While the recorder is disabled this is a no-op guard (one
/// atomic load, no allocation, no clock read).
#[inline]
pub fn span(name: &'static str, cat: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard(None);
    }
    let depth = DEPTH.with(|d| {
        let v = d.get();
        d.set(v + 1);
        v
    });
    SpanGuard(Some(OpenSpan { name, cat, depth, start: Instant::now() }))
}

struct OpenSpan {
    name: &'static str,
    cat: &'static str,
    depth: u32,
    start: Instant,
}

/// An open span. Dropping it records the completed event (even if the
/// recorder was disabled mid-span, so long spans never vanish).
pub struct SpanGuard(Option<OpenSpan>);

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(s) = self.0.take() {
            DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
            let ts_us =
                s.start.duration_since(origin()).as_micros() as u64;
            push(Event {
                name: s.name,
                cat: s.cat,
                ts_us,
                dur_us: s.start.elapsed().as_micros() as u64,
                tid: TID.with(|t| *t),
                depth: s.depth,
                value: None,
            });
        }
    }
}

/// Everything one [`drain`] returned.
#[derive(Clone, Debug)]
pub struct Trace {
    pub events: Vec<Event>,
    /// Events the bounded ring discarded (oldest-first) before this
    /// drain.
    pub dropped: u64,
}

/// One row of [`Trace::phase_summary`].
#[derive(Clone, Debug)]
pub struct PhaseStat {
    pub name: &'static str,
    pub hist: Hist,
}

impl Trace {
    /// Render as Chrome `trace_event` JSON: spans become complete
    /// (`"ph":"X"`) events, counter events `"ph":"C"`, timestamps in µs.
    /// Load the file at `chrome://tracing` or <https://ui.perfetto.dev>.
    pub fn to_chrome_json(&self) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                let mut fields = vec![
                    ("name", Json::Str(e.name.to_string())),
                    ("cat", Json::Str(e.cat.to_string())),
                    ("ph", Json::Str(
                        if e.value.is_some() { "C" } else { "X" }.to_string(),
                    )),
                    ("ts", Json::Num(e.ts_us as f64)),
                    ("pid", Json::Num(1.0)),
                    ("tid", Json::Num(e.tid as f64)),
                ];
                match e.value {
                    Some(v) => fields.push((
                        "args",
                        Json::obj(vec![("value", Json::Num(v))]),
                    )),
                    None => fields.push(("dur", Json::Num(e.dur_us as f64))),
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::Str("ms".to_string())),
            ("droppedEvents", Json::Num(self.dropped as f64)),
        ])
    }

    /// One JSON object per line (grep/jq-friendly).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for e in &self.events {
            let mut fields = vec![
                ("name", Json::Str(e.name.to_string())),
                ("cat", Json::Str(e.cat.to_string())),
                ("ts_us", Json::Num(e.ts_us as f64)),
                ("dur_us", Json::Num(e.dur_us as f64)),
                ("tid", Json::Num(e.tid as f64)),
                ("depth", Json::Num(e.depth as f64)),
            ];
            if let Some(v) = e.value {
                fields.push(("value", Json::Num(v)));
            }
            out.push_str(&Json::obj(fields).to_string());
            out.push('\n');
        }
        out
    }

    /// Aggregate the spans by name into latency histograms, ordered by
    /// total time (descending) — the CLI's per-phase timing table.
    pub fn phase_summary(&self) -> Vec<PhaseStat> {
        let mut phases: Vec<PhaseStat> = Vec::new();
        for e in &self.events {
            if e.value.is_some() {
                continue;
            }
            let secs = e.dur_us as f64 * 1e-6;
            match phases.iter_mut().find(|p| p.name == e.name) {
                Some(p) => p.hist.record(secs),
                None => {
                    let mut hist = Hist::latency();
                    hist.record(secs);
                    phases.push(PhaseStat { name: e.name, hist });
                }
            }
        }
        phases.sort_by(|a, b| b.hist.sum().total_cmp(&a.hist.sum()));
        phases
    }

    /// Package this drain as one process track of a merged fleet trace.
    pub fn into_track(self, pid: u64, label: &str) -> TraceTrack {
        TraceTrack {
            pid,
            label: label.to_string(),
            events: self.events.iter().map(Event::to_owned_event).collect(),
            dropped: self.dropped,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The recorder is process-global; tests that enable it serialize
    /// on this lock so parallel test threads cannot interleave rings.
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn ring_overflow_keeps_newest_and_counts_dropped() {
        let _l = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        enable_with_capacity(8);
        for i in 0..20 {
            event("tick", "test", i as f64);
        }
        disable();
        let t = drain();
        assert_eq!(t.events.len(), 8);
        assert_eq!(t.dropped, 12);
        // the survivors are the 8 most recent
        let values: Vec<f64> =
            t.events.iter().filter_map(|e| e.value).collect();
        assert_eq!(values, (12..20).map(|i| i as f64).collect::<Vec<_>>());
        assert_eq!(t.to_chrome_json().get("droppedEvents")
            .and_then(Json::as_f64), Some(12.0));
    }

    #[test]
    fn spans_nest_and_disabled_recorder_is_silent() {
        let _l = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        disable();
        drain();
        {
            let _g = span("ignored", "test");
        }
        assert_eq!(drain().events.len(), 0, "disabled guards record nothing");

        enable_with_capacity(64);
        {
            let _outer = span("outer", "test");
            std::thread::sleep(std::time::Duration::from_millis(1));
            let _inner = span("inner", "test");
        }
        disable();
        let t = drain();
        assert_eq!(t.events.len(), 2);
        // guards drop inner-first
        let inner = &t.events[0];
        let outer = &t.events[1];
        assert_eq!(inner.name, "inner");
        assert_eq!(inner.depth, 1);
        assert_eq!(outer.name, "outer");
        assert_eq!(outer.depth, 0);
        assert!(outer.dur_us >= inner.dur_us);
        assert!(outer.ts_us <= inner.ts_us);
        assert_eq!(inner.tid, outer.tid);

        // exports render both events
        let chrome = t.to_chrome_json();
        let rendered = chrome.to_string();
        assert!(rendered.contains("\"traceEvents\""));
        assert!(rendered.contains("\"ph\":\"X\""));
        assert_eq!(t.to_jsonl().lines().count(), 2);

        // phase table: one row per span name, outer's total ≥ inner's
        let phases = t.phase_summary();
        assert_eq!(phases.len(), 2);
        assert_eq!(phases[0].name, "outer");
        assert_eq!(phases[0].hist.count(), 1);
    }

    #[test]
    fn merged_tracks_render_per_pid_rows_with_metadata() {
        let mk = |name: &str, ts: u64| OwnedEvent {
            name: name.to_string(),
            cat: "test".to_string(),
            ts_us: ts,
            dur_us: 5,
            tid: 1,
            depth: 0,
            value: None,
        };
        let tracks = vec![
            TraceTrack {
                pid: 1,
                label: "leader".to_string(),
                events: vec![mk("gather", 10)],
                dropped: 2,
            },
            TraceTrack {
                pid: 3,
                label: "worker-1".to_string(),
                events: vec![mk("score_scan", 12), mk("column_serve", 20)],
                dropped: 1,
            },
        ];
        let chrome = merged_chrome_json(&tracks);
        let rendered = chrome.to_string();
        assert!(rendered.contains("\"process_name\""));
        assert!(rendered.contains("\"worker-1\""));
        assert_eq!(
            chrome.get("droppedEvents").and_then(Json::as_f64),
            Some(3.0)
        );
        let events = chrome
            .get("traceEvents")
            .and_then(Json::as_arr)
            .expect("traceEvents");
        // 2 metadata events + 3 spans
        assert_eq!(events.len(), 5);
        let pids: Vec<f64> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .map(|e| e.get("pid").and_then(Json::as_f64).unwrap())
            .collect();
        assert_eq!(pids, vec![1.0, 3.0, 3.0]);

        let jsonl = merged_jsonl(&tracks);
        assert_eq!(jsonl.lines().count(), 3);
        assert!(jsonl.contains("\"process\":\"leader\""));

        // Event → OwnedEvent keeps every field
        let ev = Event {
            name: "x",
            cat: "c",
            ts_us: 7,
            dur_us: 9,
            tid: 4,
            depth: 2,
            value: Some(1.5),
        };
        let owned = ev.to_owned_event();
        assert_eq!(owned.name, "x");
        assert_eq!(owned.ts_us, 7);
        assert_eq!(owned.dur_us, 9);
        assert_eq!(owned.tid, 4);
        assert_eq!(owned.depth, 2);
        assert_eq!(owned.value, Some(1.5));
    }
}
