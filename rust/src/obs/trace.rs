//! Process-global span/event recorder for hot-path tracing.
//!
//! Off by default: every instrumentation point costs one relaxed atomic
//! load until [`enable`] is called, so the guards stay in the sampling,
//! coordinator, engine, and task hot paths unconditionally. When
//! enabled, [`span`] guards record *complete* events (start + duration,
//! monotonic µs since [`enable`]) into a bounded ring buffer — when the
//! buffer fills, the **oldest** events are dropped and counted, so a
//! long run keeps its most recent window and the export says exactly
//! how much is missing.
//!
//! Nesting is tracked per thread (a thread-local depth counter — the
//! span stack), so exports preserve parent/child structure: Chrome's
//! trace viewer nests complete events on the same thread row by
//! timestamp containment, and the JSONL export carries an explicit
//! `depth` field.
//!
//! Two export shapes, both built on [`drain`]:
//! * [`Trace::to_chrome_json`] — the Chrome `trace_event` format
//!   (`chrome://tracing`, <https://ui.perfetto.dev>).
//! * [`Trace::to_jsonl`] — one JSON object per line, grep-friendly.
//!
//! [`Trace::phase_summary`] aggregates the spans per name into
//! [`Hist`]s — the CLI's per-phase timing table.

use super::hist::Hist;
use crate::util::json::Json;
use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Default ring capacity: at ~56 bytes/event this is ~3.7 MiB, enough
/// for a 450-column selection's every phase with plenty of headroom.
pub const DEFAULT_CAPACITY: usize = 1 << 16;

/// One recorded event. `dur_us == 0` with a `value` is a counter
/// sample (e.g. per-frame wire bytes); otherwise a completed span.
#[derive(Clone, Debug)]
pub struct Event {
    pub name: &'static str,
    /// Category: the subsystem that emitted it (`sampling`, `coord`,
    /// `engine`, `tasks`, `net`, `server`).
    pub cat: &'static str,
    /// Start, µs since the recorder was enabled (monotonic).
    pub ts_us: u64,
    /// Span duration in µs (0 for counter events).
    pub dur_us: u64,
    /// Recorder-assigned thread id (dense, starts at 1).
    pub tid: u64,
    /// Nesting depth on its thread at record time (0 = top level).
    pub depth: u32,
    /// Counter payload (wire bytes, batch sizes, …).
    pub value: Option<f64>,
}

struct Ring {
    events: VecDeque<Event>,
    capacity: usize,
    dropped: u64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static RING: Mutex<Option<Ring>> = Mutex::new(None);
static ORIGIN: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    static DEPTH: Cell<u32> = const { Cell::new(0) };
}

fn origin() -> Instant {
    *ORIGIN.get_or_init(Instant::now)
}

/// Is the recorder live? One relaxed load — the entire disabled-path
/// cost of an instrumentation point.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Start recording with the default ring capacity. Clears any
/// previously recorded events.
pub fn enable() {
    enable_with_capacity(DEFAULT_CAPACITY);
}

/// Start recording into a ring of `capacity` events (≥ 1). Clears any
/// previously recorded events and resets the dropped counter.
pub fn enable_with_capacity(capacity: usize) {
    let capacity = capacity.max(1);
    origin(); // pin the monotonic zero before the first event
    let mut ring = RING.lock().unwrap_or_else(|e| e.into_inner());
    *ring = Some(Ring { events: VecDeque::new(), capacity, dropped: 0 });
    ENABLED.store(true, Ordering::Relaxed);
}

/// Stop recording. Events already in the ring stay until [`drain`].
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Take everything recorded so far (and the count of events the ring
/// dropped), leaving an empty ring. The recorder stays in its current
/// enabled/disabled state.
pub fn drain() -> Trace {
    let mut ring = RING.lock().unwrap_or_else(|e| e.into_inner());
    match ring.as_mut() {
        None => Trace { events: Vec::new(), dropped: 0 },
        Some(r) => {
            let events = std::mem::take(&mut r.events).into();
            let dropped = std::mem::replace(&mut r.dropped, 0);
            Trace { events, dropped }
        }
    }
}

fn push(ev: Event) {
    let mut ring = RING.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(r) = ring.as_mut() {
        if r.events.len() == r.capacity {
            r.events.pop_front();
            r.dropped += 1;
        }
        r.events.push_back(ev);
    }
}

/// Record a counter event (a point-in-time value, e.g. the byte size
/// of one wire frame). No-op while disabled.
#[inline]
pub fn event(name: &'static str, cat: &'static str, value: f64) {
    if !enabled() {
        return;
    }
    push(Event {
        name,
        cat,
        ts_us: origin().elapsed().as_micros() as u64,
        dur_us: 0,
        tid: TID.with(|t| *t),
        depth: DEPTH.with(|d| d.get()),
        value: Some(value),
    });
}

/// Open a span; the returned guard records a complete event when it
/// drops. While the recorder is disabled this is a no-op guard (one
/// atomic load, no allocation, no clock read).
#[inline]
pub fn span(name: &'static str, cat: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard(None);
    }
    let depth = DEPTH.with(|d| {
        let v = d.get();
        d.set(v + 1);
        v
    });
    SpanGuard(Some(OpenSpan { name, cat, depth, start: Instant::now() }))
}

struct OpenSpan {
    name: &'static str,
    cat: &'static str,
    depth: u32,
    start: Instant,
}

/// An open span. Dropping it records the completed event (even if the
/// recorder was disabled mid-span, so long spans never vanish).
pub struct SpanGuard(Option<OpenSpan>);

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(s) = self.0.take() {
            DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
            let ts_us =
                s.start.duration_since(origin()).as_micros() as u64;
            push(Event {
                name: s.name,
                cat: s.cat,
                ts_us,
                dur_us: s.start.elapsed().as_micros() as u64,
                tid: TID.with(|t| *t),
                depth: s.depth,
                value: None,
            });
        }
    }
}

/// Everything one [`drain`] returned.
#[derive(Clone, Debug)]
pub struct Trace {
    pub events: Vec<Event>,
    /// Events the bounded ring discarded (oldest-first) before this
    /// drain.
    pub dropped: u64,
}

/// One row of [`Trace::phase_summary`].
#[derive(Clone, Debug)]
pub struct PhaseStat {
    pub name: &'static str,
    pub hist: Hist,
}

impl Trace {
    /// Render as Chrome `trace_event` JSON: spans become complete
    /// (`"ph":"X"`) events, counter events `"ph":"C"`, timestamps in µs.
    /// Load the file at `chrome://tracing` or <https://ui.perfetto.dev>.
    pub fn to_chrome_json(&self) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                let mut fields = vec![
                    ("name", Json::Str(e.name.to_string())),
                    ("cat", Json::Str(e.cat.to_string())),
                    ("ph", Json::Str(
                        if e.value.is_some() { "C" } else { "X" }.to_string(),
                    )),
                    ("ts", Json::Num(e.ts_us as f64)),
                    ("pid", Json::Num(1.0)),
                    ("tid", Json::Num(e.tid as f64)),
                ];
                match e.value {
                    Some(v) => fields.push((
                        "args",
                        Json::obj(vec![("value", Json::Num(v))]),
                    )),
                    None => fields.push(("dur", Json::Num(e.dur_us as f64))),
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::Str("ms".to_string())),
            ("droppedEvents", Json::Num(self.dropped as f64)),
        ])
    }

    /// One JSON object per line (grep/jq-friendly).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for e in &self.events {
            let mut fields = vec![
                ("name", Json::Str(e.name.to_string())),
                ("cat", Json::Str(e.cat.to_string())),
                ("ts_us", Json::Num(e.ts_us as f64)),
                ("dur_us", Json::Num(e.dur_us as f64)),
                ("tid", Json::Num(e.tid as f64)),
                ("depth", Json::Num(e.depth as f64)),
            ];
            if let Some(v) = e.value {
                fields.push(("value", Json::Num(v)));
            }
            out.push_str(&Json::obj(fields).to_string());
            out.push('\n');
        }
        out
    }

    /// Aggregate the spans by name into latency histograms, ordered by
    /// total time (descending) — the CLI's per-phase timing table.
    pub fn phase_summary(&self) -> Vec<PhaseStat> {
        let mut phases: Vec<PhaseStat> = Vec::new();
        for e in &self.events {
            if e.value.is_some() {
                continue;
            }
            let secs = e.dur_us as f64 * 1e-6;
            match phases.iter_mut().find(|p| p.name == e.name) {
                Some(p) => p.hist.record(secs),
                None => {
                    let mut hist = Hist::latency();
                    hist.record(secs);
                    phases.push(PhaseStat { name: e.name, hist });
                }
            }
        }
        phases.sort_by(|a, b| b.hist.sum().total_cmp(&a.hist.sum()));
        phases
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The recorder is process-global; tests that enable it serialize
    /// on this lock so parallel test threads cannot interleave rings.
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn ring_overflow_keeps_newest_and_counts_dropped() {
        let _l = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        enable_with_capacity(8);
        for i in 0..20 {
            event("tick", "test", i as f64);
        }
        disable();
        let t = drain();
        assert_eq!(t.events.len(), 8);
        assert_eq!(t.dropped, 12);
        // the survivors are the 8 most recent
        let values: Vec<f64> =
            t.events.iter().filter_map(|e| e.value).collect();
        assert_eq!(values, (12..20).map(|i| i as f64).collect::<Vec<_>>());
        assert_eq!(t.to_chrome_json().get("droppedEvents")
            .and_then(Json::as_f64), Some(12.0));
    }

    #[test]
    fn spans_nest_and_disabled_recorder_is_silent() {
        let _l = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        disable();
        drain();
        {
            let _g = span("ignored", "test");
        }
        assert_eq!(drain().events.len(), 0, "disabled guards record nothing");

        enable_with_capacity(64);
        {
            let _outer = span("outer", "test");
            std::thread::sleep(std::time::Duration::from_millis(1));
            let _inner = span("inner", "test");
        }
        disable();
        let t = drain();
        assert_eq!(t.events.len(), 2);
        // guards drop inner-first
        let inner = &t.events[0];
        let outer = &t.events[1];
        assert_eq!(inner.name, "inner");
        assert_eq!(inner.depth, 1);
        assert_eq!(outer.name, "outer");
        assert_eq!(outer.depth, 0);
        assert!(outer.dur_us >= inner.dur_us);
        assert!(outer.ts_us <= inner.ts_us);
        assert_eq!(inner.tid, outer.tid);

        // exports render both events
        let chrome = t.to_chrome_json();
        let rendered = chrome.to_string();
        assert!(rendered.contains("\"traceEvents\""));
        assert!(rendered.contains("\"ph\":\"X\""));
        assert_eq!(t.to_jsonl().lines().count(), 2);

        // phase table: one row per span name, outer's total ≥ inner's
        let phases = t.phase_summary();
        assert_eq!(phases.len(), 2);
        assert_eq!(phases[0].name, "outer");
        assert_eq!(phases[0].hist.count(), 1);
    }
}
