//! Observability: histograms, structured tracing, and Prometheus text
//! exposition — dependency-free, shared by every layer of the stack.
//!
//! The paper's claim is about *where time goes* (oASIS matches adaptive
//! accuracy "at a fraction of the computational cost"), so the stack has
//! to be able to show a per-phase cost breakdown of its own hot paths:
//!
//! * [`hist`] — log₂-bucketed histograms with p50/p90/p99 quantile
//!   estimation. They back the per-session step-latency stats, the
//!   server's per-endpoint request-duration histograms, and the CLI's
//!   per-phase timing table.
//! * [`trace`] — a process-global span/event recorder (thread-local span
//!   stack, bounded ring buffer, monotonic timestamps) that the hot
//!   paths write into when tracing is enabled: sampling step phases
//!   (score scan, column fetch, factor update), engine resolve, task
//!   fit/predict, coordinator rounds (gather, arbitrate, reshard), and
//!   per-frame wire bytes. Exports as Chrome `trace_event` JSON
//!   (load it at `chrome://tracing` or <https://ui.perfetto.dev>) or
//!   JSONL; `oasis approximate --trace out.json` drives it end to end.
//! * [`prom`] — Prometheus text exposition (version 0.0.4): counters,
//!   gauges, cumulative `_bucket`/`_sum`/`_count` histogram series, and
//!   a self-contained exposition validator the CI smoke jobs run via
//!   `oasis promcheck`. The server serves it from
//!   `GET /metrics?format=prometheus` (or `Accept: text/plain`).
//! * [`log`] — a leveled, structured (JSON-lines capable) logger that
//!   replaces ad-hoc stderr prints in the server, coordinator, and
//!   worker paths; `--log-level`/`--log-json` on `serve`, `parallel`,
//!   and `worker` configure it.
//!
//! In the oASIS-P fleet the tracing pillar is *distributed*: worker
//! processes record into their own rings and ship
//! [`trace::OwnedEvent`] chunks leader-ward over the coordinator wire
//! protocol; the leader merges everything into per-process
//! [`trace::TraceTrack`]s for one Chrome timeline.
//!
//! Tracing is off by default and costs one relaxed atomic load per
//! guard when disabled, so instrumentation stays in the hot paths
//! unconditionally.

pub mod hist;
pub mod log;
pub mod prom;
pub mod trace;

pub use hist::Hist;
pub use trace::{span, SpanGuard};
