//! Prometheus text exposition (format version 0.0.4) and a
//! self-contained exposition validator.
//!
//! [`PromText`] builds an exposition page: `# HELP`/`# TYPE` headers,
//! counter/gauge samples, and [`Hist`]s rendered as the cumulative
//! `_bucket{le=…}` / `_sum` / `_count` series Prometheus histograms
//! require. [`validate`] checks a page for the properties scrapers
//! depend on — metric/label name syntax, parseable values, `TYPE`
//! declared before first use, strictly increasing `le` bounds,
//! non-decreasing cumulative bucket counts, and a `+Inf` bucket that
//! equals `_count` — and backs both the golden-format tests and the
//! `oasis promcheck` CI smoke checker.
//!
//! The server serves the page from `GET /metrics?format=prometheus`
//! (or `Accept: text/plain`); see the [`server`](crate::server) docs
//! for the metric families.

use super::hist::Hist;
use std::collections::BTreeMap;

/// The content type Prometheus scrapers expect.
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

/// An exposition page under construction.
#[derive(Default)]
pub struct PromText {
    buf: String,
}

/// Escape a label value: backslash, double quote, newline.
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Render a sample value (`{}` keeps integers exact; non-finite spell
/// the Prometheus way).
fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

impl PromText {
    pub fn new() -> PromText {
        PromText::default()
    }

    /// Declare a metric family: `# HELP` and `# TYPE` lines. Call once
    /// per family, before its samples.
    pub fn family(&mut self, name: &str, help: &str, ty: &str) {
        self.buf.push_str("# HELP ");
        self.buf.push_str(name);
        self.buf.push(' ');
        self.buf.push_str(help);
        self.buf.push_str("\n# TYPE ");
        self.buf.push_str(name);
        self.buf.push(' ');
        self.buf.push_str(ty);
        self.buf.push('\n');
    }

    /// One sample line: `name{labels} value`.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.buf.push_str(name);
        if !labels.is_empty() {
            self.buf.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.buf.push(',');
                }
                self.buf.push_str(k);
                self.buf.push_str("=\"");
                self.buf.push_str(&escape_label(v));
                self.buf.push('"');
            }
            self.buf.push('}');
        }
        self.buf.push(' ');
        self.buf.push_str(&fmt_value(value));
        self.buf.push('\n');
    }

    /// Declare and emit an unlabeled counter in one call.
    pub fn counter(&mut self, name: &str, help: &str, value: f64) {
        self.family(name, help, "counter");
        self.sample(name, &[], value);
    }

    /// Declare and emit an unlabeled gauge in one call.
    pub fn gauge(&mut self, name: &str, help: &str, value: f64) {
        self.family(name, help, "gauge");
        self.sample(name, &[], value);
    }

    /// Emit one histogram instance's samples (`_bucket` series ending
    /// in `+Inf`, then `_sum` and `_count`). Declare the family once
    /// with [`PromText::family`]`(name, help, "histogram")` before the
    /// first instance; `labels` distinguish instances (endpoint,
    /// session, …).
    pub fn histogram(&mut self, name: &str, labels: &[(&str, &str)], h: &Hist) {
        let bucket = format!("{name}_bucket");
        for (le, cum) in h.cumulative_buckets() {
            let le_s = fmt_value(le);
            let mut with_le = labels.to_vec();
            with_le.push(("le", &le_s));
            self.sample(&bucket, &with_le, cum as f64);
        }
        let mut with_le = labels.to_vec();
        with_le.push(("le", "+Inf"));
        self.sample(&bucket, &with_le, h.count() as f64);
        self.sample(&format!("{name}_sum"), labels, h.sum());
        self.sample(&format!("{name}_count"), labels, h.count() as f64);
    }

    pub fn finish(self) -> String {
        self.buf
    }
}

fn valid_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn parse_value(s: &str) -> Result<f64, String> {
    match s {
        "+Inf" => Ok(f64::INFINITY),
        "-Inf" => Ok(f64::NEG_INFINITY),
        "NaN" => Ok(f64::NAN),
        other => other
            .parse::<f64>()
            .map_err(|_| format!("unparseable sample value '{other}'")),
    }
}

/// One parsed sample line.
struct Sample {
    name: String,
    labels: Vec<(String, String)>,
    value: f64,
}

/// Parse `name{k="v",…} value`. Exposition from well-behaved writers
/// only — escapes inside label values are honored, exotic whitespace is
/// not.
fn parse_sample(line: &str, lineno: usize) -> Result<Sample, String> {
    let err = |m: String| format!("line {lineno}: {m}");
    let (name_part, rest) = match line.find('{') {
        Some(brace) => {
            let close = line
                .rfind('}')
                .ok_or_else(|| err("'{' without '}'".into()))?;
            (&line[..brace], line[brace..=close].to_string())
        }
        None => match line.find(' ') {
            Some(sp) => (&line[..sp], String::new()),
            None => return Err(err("no value on sample line".into())),
        },
    };
    if !valid_name(name_part) {
        return Err(err(format!("invalid metric name '{name_part}'")));
    }
    let mut labels = Vec::new();
    let value_str;
    if rest.is_empty() {
        value_str = line[name_part.len()..].trim().to_string();
    } else {
        // parse the {...} label block with escape-aware scanning
        let inner = &rest[1..rest.len() - 1];
        let mut chars = inner.chars().peekable();
        while chars.peek().is_some() {
            let mut key = String::new();
            for c in chars.by_ref() {
                if c == '=' {
                    break;
                }
                key.push(c);
            }
            if !valid_label_name(key.trim()) {
                return Err(err(format!("invalid label name '{key}'")));
            }
            if chars.next() != Some('"') {
                return Err(err(format!("label '{key}' value not quoted")));
            }
            let mut val = String::new();
            let mut escaped = false;
            loop {
                match chars.next() {
                    None => return Err(err("unterminated label value".into())),
                    Some('\\') if !escaped => escaped = true,
                    Some('"') if !escaped => break,
                    Some(c) => {
                        val.push(if escaped && c == 'n' { '\n' } else { c });
                        escaped = false;
                    }
                }
            }
            labels.push((key.trim().to_string(), val));
            if chars.peek() == Some(&',') {
                chars.next();
            }
        }
        value_str = line[name_part.len() + rest.len()..].trim().to_string();
    }
    let value = parse_value(&value_str).map_err(err)?;
    Ok(Sample { name: name_part.to_string(), labels, value })
}

/// The family a sample belongs to (strips histogram series suffixes).
fn family_of(name: &str) -> &str {
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(stripped) = name.strip_suffix(suffix) {
            return stripped;
        }
    }
    name
}

/// Validate an exposition page. Checks, in order of discovery:
/// comment syntax, metric and label name syntax, value parseability,
/// `# TYPE` declared before a family's first sample, and for every
/// histogram series (grouped by family + non-`le` labels): strictly
/// increasing `le` bounds, non-decreasing cumulative counts, a `+Inf`
/// bucket, and `+Inf == _count`.
pub fn validate(text: &str) -> Result<(), String> {
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    // (family, non-le labels) -> ordered (le, cumulative) pairs
    let mut series: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
    let mut counts: BTreeMap<String, f64> = BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        let lineno = i + 1;
        if line.trim().is_empty() {
            continue;
        }
        if let Some(comment) = line.strip_prefix('#') {
            let comment = comment.trim_start();
            if let Some(rest) = comment.strip_prefix("TYPE ") {
                let mut parts = rest.split_whitespace();
                let (name, ty) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
                if !valid_name(name) {
                    return Err(format!("line {lineno}: TYPE for invalid name '{name}'"));
                }
                if !["counter", "gauge", "histogram", "summary", "untyped"]
                    .contains(&ty)
                {
                    return Err(format!("line {lineno}: unknown TYPE '{ty}'"));
                }
                if types.insert(name.to_string(), ty.to_string()).is_some() {
                    return Err(format!("line {lineno}: duplicate TYPE for '{name}'"));
                }
            } else if !comment.starts_with("HELP ") {
                return Err(format!("line {lineno}: unknown comment '{line}'"));
            }
            continue;
        }
        let s = parse_sample(line, lineno)?;
        let family = family_of(&s.name);
        let declared = types.contains_key(family) || types.contains_key(&s.name);
        if !declared {
            return Err(format!(
                "line {lineno}: sample '{}' before its # TYPE declaration",
                s.name
            ));
        }
        let histogram = types.get(family).map(String::as_str) == Some("histogram");
        if histogram && s.name.ends_with("_bucket") {
            let le = s
                .labels
                .iter()
                .find(|(k, _)| k == "le")
                .ok_or_else(|| format!("line {lineno}: bucket without le label"))?;
            let bound = parse_value(&le.1)
                .map_err(|m| format!("line {lineno}: {m}"))?;
            let key = series_key(family, &s.labels);
            series.entry(key).or_default().push((bound, s.value));
        } else if histogram && s.name.ends_with("_count") {
            counts.insert(series_key(family, &s.labels), s.value);
        }
    }
    for (key, buckets) in &series {
        for w in buckets.windows(2) {
            if w[1].0 <= w[0].0 {
                return Err(format!(
                    "histogram {key}: le bounds not increasing ({} after {})",
                    w[1].0, w[0].0
                ));
            }
            if w[1].1 < w[0].1 {
                return Err(format!(
                    "histogram {key}: cumulative count decreases at le={}",
                    w[1].0
                ));
            }
        }
        let last = buckets.last().expect("series entries are non-empty");
        if last.0 != f64::INFINITY {
            return Err(format!("histogram {key}: missing +Inf bucket"));
        }
        if let Some(&count) = counts.get(key) {
            if count != last.1 {
                return Err(format!(
                    "histogram {key}: +Inf bucket {} != _count {count}",
                    last.1
                ));
            }
        } else {
            return Err(format!("histogram {key}: missing _count"));
        }
    }
    Ok(())
}

/// Group key for one histogram instance: family + its non-`le` labels.
fn series_key(family: &str, labels: &[(String, String)]) -> String {
    let mut key = family.to_string();
    for (k, v) in labels {
        if k != "le" {
            key.push_str(&format!("|{k}={v}"));
        }
    }
    key
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden-format test: the writer's output is byte-exact and passes
    /// its own validator.
    #[test]
    fn writer_produces_golden_exposition() {
        let mut h = Hist::latency();
        for v in [0.5e-6, 3e-6, 5e-6] {
            h.record(v);
        }
        let mut page = PromText::new();
        page.counter("oasis_requests_total", "Requests served.", 42.0);
        page.gauge("oasis_uptime_seconds", "Seconds since boot.", 1.5);
        page.family(
            "oasis_step_seconds",
            "Selection step latency.",
            "histogram",
        );
        page.histogram(
            "oasis_step_seconds",
            &[("session", "a\"b")],
            &h,
        );
        let text = page.finish();
        let expected = "\
# HELP oasis_requests_total Requests served.
# TYPE oasis_requests_total counter
oasis_requests_total 42
# HELP oasis_uptime_seconds Seconds since boot.
# TYPE oasis_uptime_seconds gauge
oasis_uptime_seconds 1.5
# HELP oasis_step_seconds Selection step latency.
# TYPE oasis_step_seconds histogram
oasis_step_seconds_bucket{session=\"a\\\"b\",le=\"0.000001\"} 1
oasis_step_seconds_bucket{session=\"a\\\"b\",le=\"0.000002\"} 1
oasis_step_seconds_bucket{session=\"a\\\"b\",le=\"0.000004\"} 2
oasis_step_seconds_bucket{session=\"a\\\"b\",le=\"0.000008\"} 3
oasis_step_seconds_bucket{session=\"a\\\"b\",le=\"+Inf\"} 3
oasis_step_seconds_sum{session=\"a\\\"b\"} 0.0000085
oasis_step_seconds_count{session=\"a\\\"b\"} 3
";
        assert_eq!(text, expected);
        validate(&text).expect("own output must validate");
    }

    #[test]
    fn validator_rejects_malformed_pages() {
        // sample before TYPE
        assert!(validate("oasis_x_total 1\n").is_err());
        // bad metric name
        assert!(validate("# TYPE 9bad counter\n").is_err());
        // unparseable value
        assert!(
            validate("# TYPE a counter\n# HELP a h\na one\n").is_err()
        );
        // decreasing cumulative bucket counts
        let page = "\
# TYPE h histogram
h_bucket{le=\"1\"} 5
h_bucket{le=\"2\"} 3
h_bucket{le=\"+Inf\"} 5
h_sum 1
h_count 5
";
        let err = validate(page).unwrap_err();
        assert!(err.contains("decreases"), "{err}");
        // non-increasing le bounds
        let page = "\
# TYPE h histogram
h_bucket{le=\"2\"} 1
h_bucket{le=\"1\"} 2
h_bucket{le=\"+Inf\"} 2
h_count 2
";
        assert!(validate(page).unwrap_err().contains("not increasing"));
        // +Inf must match _count
        let page = "\
# TYPE h histogram
h_bucket{le=\"1\"} 1
h_bucket{le=\"+Inf\"} 1
h_count 2
";
        assert!(validate(page).unwrap_err().contains("_count"));
        // missing +Inf
        let page = "\
# TYPE h histogram
h_bucket{le=\"1\"} 1
h_count 1
";
        assert!(validate(page).unwrap_err().contains("+Inf"));
    }

    #[test]
    fn label_escapes_round_trip_through_the_parser() {
        let mut page = PromText::new();
        page.family("g", "h", "gauge");
        page.sample("g", &[("path", "a\\b\"c\nd")], 1.0);
        let text = page.finish();
        validate(&text).expect("escaped labels must parse");
        let s = parse_sample(text.lines().last().unwrap(), 3).unwrap();
        assert_eq!(s.labels[0].1, "a\\b\"c\nd");
    }
}
