//! Leveled, structured logging — the second observability pillar.
//!
//! A process-global logger with four levels (`error` > `warn` > `info`
//! > `debug`), two renderings, and zero dependencies:
//!
//! * **text** (default): `TS LEVEL target: message key=value …` — what
//!   a human wants on a terminal.
//! * **JSON lines** ([`set_json`]): one object per line with `ts`,
//!   `level`, `target`, `msg`, and every structured field — what a log
//!   pipeline wants. `oasis serve --log-json` switches it on.
//!
//! Lines below the configured [`Level`] cost one relaxed atomic load.
//! Everything goes to stderr (stdout stays reserved for command
//! output), plus an optional in-process capture sink that tests use to
//! assert on emitted lines without scraping a child's stderr.
//!
//! Structured fields are `(&str, String)` pairs; the helpers
//! [`error`], [`warn`], [`info`], and [`debug`] cover the common case:
//!
//! ```
//! oasis::obs::log::info(
//!     "server",
//!     "request",
//!     &[("request_id", "r-42".to_string()), ("status", "200".to_string())],
//! );
//! ```

use crate::util::json::Json;
use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

/// Log severity. Ordered so `Error < Warn < Info < Debug` — a line is
/// emitted when its level is ≤ the configured threshold.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            _ => Level::Debug,
        }
    }
}

/// Parse a `--log-level` argument (case-insensitive).
pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" | "warning" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        _ => None,
    }
}

static THRESHOLD: AtomicU8 = AtomicU8::new(Level::Info as u8);
static JSON_MODE: AtomicBool = AtomicBool::new(false);
static CAPTURE: Mutex<Option<Vec<String>>> = Mutex::new(None);

/// Set the emission threshold (default [`Level::Info`]).
pub fn set_level(level: Level) {
    THRESHOLD.store(level as u8, Ordering::Relaxed);
}

/// The current emission threshold.
pub fn level() -> Level {
    Level::from_u8(THRESHOLD.load(Ordering::Relaxed))
}

/// Switch between JSON-lines (`true`) and text rendering.
pub fn set_json(on: bool) {
    JSON_MODE.store(on, Ordering::Relaxed);
}

/// Would a line at `l` be emitted right now? One relaxed load — the
/// entire cost of a suppressed log site.
#[inline]
pub fn enabled(l: Level) -> bool {
    (l as u8) <= THRESHOLD.load(Ordering::Relaxed)
}

/// Start capturing rendered lines in-process (test sink). Lines still
/// go to stderr too.
pub fn capture_start() {
    let mut cap = CAPTURE.lock().unwrap_or_else(|e| e.into_inner());
    *cap = Some(Vec::new());
}

/// Stop capturing and take everything captured since
/// [`capture_start`].
pub fn capture_take() -> Vec<String> {
    let mut cap = CAPTURE.lock().unwrap_or_else(|e| e.into_inner());
    cap.take().unwrap_or_default()
}

fn now_unix() -> f64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

fn render(
    level: Level,
    target: &str,
    msg: &str,
    fields: &[(&str, String)],
) -> String {
    if JSON_MODE.load(Ordering::Relaxed) {
        let mut obj = vec![
            ("ts", Json::Num((now_unix() * 1e3).round() / 1e3)),
            ("level", Json::Str(level.as_str().to_string())),
            ("target", Json::Str(target.to_string())),
            ("msg", Json::Str(msg.to_string())),
        ];
        for (k, v) in fields {
            obj.push((k, Json::Str(v.clone())));
        }
        Json::obj(obj).to_string()
    } else {
        let mut line = format!(
            "[{:.3}] {:5} {}: {}",
            now_unix(),
            level.as_str().to_uppercase(),
            target,
            msg
        );
        for (k, v) in fields {
            line.push_str(&format!(" {k}={v}"));
        }
        line
    }
}

/// Emit one structured line at `level` (no-op below the threshold).
pub fn log(level: Level, target: &str, msg: &str, fields: &[(&str, String)]) {
    if !enabled(level) {
        return;
    }
    let line = render(level, target, msg, fields);
    {
        let mut cap = CAPTURE.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(buf) = cap.as_mut() {
            buf.push(line.clone());
        }
    }
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "{line}");
}

/// [`log`] at [`Level::Error`].
pub fn error(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Error, target, msg, fields);
}

/// [`log`] at [`Level::Warn`].
pub fn warn(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Warn, target, msg, fields);
}

/// [`log`] at [`Level::Info`].
pub fn info(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Info, target, msg, fields);
}

/// [`log`] at [`Level::Debug`].
pub fn debug(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Debug, target, msg, fields);
}

/// Apply the shared `--log-level LEVEL` / `--log-json` CLI flags.
/// Returns an error string for an unknown level name.
pub fn configure_from_args(
    level_arg: Option<&str>,
    json: bool,
) -> Result<(), String> {
    if let Some(s) = level_arg {
        match parse_level(s) {
            Some(l) => set_level(l),
            None => {
                return Err(format!(
                    "unknown log level {s:?} (want error|warn|info|debug)"
                ))
            }
        }
    }
    set_json(json);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The logger is process-global; tests serialize on this lock.
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn threshold_filters_and_fields_render_in_both_modes() {
        let _l = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_level(Level::Info);
        set_json(false);
        capture_start();
        debug("test", "hidden", &[]);
        info("test", "shown", &[("session", "a".to_string())]);
        let lines = capture_take();
        assert_eq!(lines.len(), 1, "debug below info threshold: {lines:?}");
        assert!(lines[0].contains("INFO"));
        assert!(lines[0].contains("shown"));
        assert!(lines[0].contains("session=a"));

        set_json(true);
        capture_start();
        warn("net", "drop", &[("worker", "2".to_string())]);
        let lines = capture_take();
        assert_eq!(lines.len(), 1);
        let j = Json::parse(&lines[0]).expect("JSON line");
        assert_eq!(j.get("level").and_then(Json::as_str), Some("warn"));
        assert_eq!(j.get("target").and_then(Json::as_str), Some("net"));
        assert_eq!(j.get("msg").and_then(Json::as_str), Some("drop"));
        assert_eq!(j.get("worker").and_then(Json::as_str), Some("2"));
        assert!(j.get("ts").and_then(Json::as_f64).unwrap() > 0.0);
        set_json(false);
    }

    #[test]
    fn level_parsing_and_flag_configuration() {
        let _l = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        assert_eq!(parse_level("DEBUG"), Some(Level::Debug));
        assert_eq!(parse_level("warning"), Some(Level::Warn));
        assert_eq!(parse_level("loud"), None);
        assert!(configure_from_args(Some("loud"), false).is_err());
        configure_from_args(Some("error"), false).unwrap();
        assert_eq!(level(), Level::Error);
        assert!(!enabled(Level::Warn));
        assert!(enabled(Level::Error));
        configure_from_args(Some("info"), false).unwrap();
    }
}
