//! Server-wide counters and per-session latency accounting for the
//! `/metrics` endpoint.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Lock-free counters shared by every connection thread.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    pub connections: AtomicU64,
    pub requests: AtomicU64,
    /// 4xx/5xx responses.
    pub errors: AtomicU64,
    pub sessions_created: AtomicU64,
    pub sessions_finished: AtomicU64,
    pub snapshots_total: AtomicU64,
    pub queries_total: AtomicU64,
    /// Session factorizations persisted via `POST /sessions/{name}/save`.
    pub artifacts_saved: AtomicU64,
    /// Stored artifacts hosted via `POST /artifacts/load`.
    pub artifacts_loaded: AtomicU64,
    /// Queries answered from loaded artifacts.
    pub artifact_queries: AtomicU64,
    /// Downstream-task models fit by the task endpoints.
    pub tasks_fitted: AtomicU64,
    /// Task requests answered from a cached fitted model.
    pub task_cache_hits: AtomicU64,
    /// Points predicted by the task endpoints.
    pub task_predictions: AtomicU64,
}

impl ServerMetrics {
    pub fn inc(c: &AtomicU64) {
        c.fetch_add(1, Ordering::Relaxed);
    }

    pub fn get(c: &AtomicU64) -> u64 {
        c.load(Ordering::Relaxed)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("connections", Json::Num(Self::get(&self.connections) as f64)),
            ("requests", Json::Num(Self::get(&self.requests) as f64)),
            ("errors", Json::Num(Self::get(&self.errors) as f64)),
            (
                "sessions_created",
                Json::Num(Self::get(&self.sessions_created) as f64),
            ),
            (
                "sessions_finished",
                Json::Num(Self::get(&self.sessions_finished) as f64),
            ),
            (
                "snapshots_total",
                Json::Num(Self::get(&self.snapshots_total) as f64),
            ),
            (
                "queries_total",
                Json::Num(Self::get(&self.queries_total) as f64),
            ),
            (
                "artifacts_saved",
                Json::Num(Self::get(&self.artifacts_saved) as f64),
            ),
            (
                "artifacts_loaded",
                Json::Num(Self::get(&self.artifacts_loaded) as f64),
            ),
            (
                "artifact_queries",
                Json::Num(Self::get(&self.artifact_queries) as f64),
            ),
            (
                "tasks_fitted",
                Json::Num(Self::get(&self.tasks_fitted) as f64),
            ),
            (
                "task_cache_hits",
                Json::Num(Self::get(&self.task_cache_hits) as f64),
            ),
            (
                "task_predictions",
                Json::Num(Self::get(&self.task_predictions) as f64),
            ),
        ])
    }
}

/// Streaming latency summary for one session's `step` calls (updated by
/// the session's actor thread, read by `/metrics`).
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyStats {
    pub count: u64,
    pub total_secs: f64,
    pub max_secs: f64,
    pub last_secs: f64,
}

impl LatencyStats {
    pub fn record(&mut self, secs: f64) {
        self.count += 1;
        self.total_secs += secs;
        self.max_secs = self.max_secs.max(secs);
        self.last_secs = secs;
    }

    pub fn mean_secs(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_secs / self.count as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("mean_ms", Json::Num(self.mean_secs() * 1e3)),
            ("last_ms", Json::Num(self.last_secs * 1e3)),
            ("max_ms", Json::Num(self.max_secs * 1e3)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_summary() {
        let mut l = LatencyStats::default();
        assert_eq!(l.mean_secs(), 0.0);
        l.record(0.010);
        l.record(0.030);
        l.record(0.020);
        assert_eq!(l.count, 3);
        assert!((l.mean_secs() - 0.020).abs() < 1e-12);
        assert_eq!(l.max_secs, 0.030);
        assert_eq!(l.last_secs, 0.020);
        let j = l.to_json();
        assert_eq!(j.get("count").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn counters_render() {
        let m = ServerMetrics::default();
        ServerMetrics::inc(&m.requests);
        ServerMetrics::inc(&m.requests);
        let j = m.to_json();
        assert_eq!(j.get("requests").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("errors").unwrap().as_usize(), Some(0));
    }
}
