//! Server-wide counters and per-endpoint request-latency histograms for
//! the `/metrics` endpoint (JSON and Prometheus renderings).
//!
//! Per-session step latencies live in
//! [`SessionStats`](super::registry::SessionStats) as an
//! [`obs::Hist`](crate::obs::Hist) — the same histogram shape used here
//! for request durations, so every latency the server reports carries
//! p50/p90/p99 estimates, not just mean/max.

use crate::obs::Hist;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Lock-free counters shared by every connection thread, plus the
/// per-endpoint request-duration histograms (mutex-guarded — recorded
/// once per request, far off any hot loop).
#[derive(Debug, Default)]
pub struct ServerMetrics {
    pub connections: AtomicU64,
    pub requests: AtomicU64,
    /// 4xx/5xx responses.
    pub errors: AtomicU64,
    /// Requests answered 429 under the `--max-rps`/`--max-rps-per-ip`
    /// caps.
    pub rate_limited: AtomicU64,
    /// Connections shed with a one-shot 503 because the accept queue was
    /// full.
    pub rejected_overload: AtomicU64,
    pub sessions_created: AtomicU64,
    pub sessions_finished: AtomicU64,
    pub snapshots_total: AtomicU64,
    pub queries_total: AtomicU64,
    /// Session factorizations persisted via `POST /sessions/{name}/save`.
    pub artifacts_saved: AtomicU64,
    /// Stored artifacts hosted via `POST /artifacts/load`.
    pub artifacts_loaded: AtomicU64,
    /// Queries answered from loaded artifacts.
    pub artifact_queries: AtomicU64,
    /// Downstream-task models fit by the task endpoints.
    pub tasks_fitted: AtomicU64,
    /// Task requests answered from a cached fitted model.
    pub task_cache_hits: AtomicU64,
    /// Points predicted by the task endpoints.
    pub task_predictions: AtomicU64,
    /// Request-duration histograms keyed by normalized endpoint label
    /// (e.g. `"POST /sessions/{name}/step"` — names collapse to
    /// placeholders so the label set stays bounded).
    pub request_hists: Mutex<BTreeMap<String, Hist>>,
    /// Task-endpoint prediction latency keyed by model
    /// (`session:{name}` / `artifact:{name}` — bounded by what the
    /// registry hosts). Kept out of [`to_json`](ServerMetrics::to_json):
    /// that rendering is counters-only and parity-checked against
    /// [`counter_triples`](ServerMetrics::counter_triples); these render
    /// under `"predict"` in the `/metrics` report instead.
    pub predict_hists: Mutex<BTreeMap<String, Hist>>,
    /// Points-per-predict-call histogram (lazy so the derived `Default`
    /// can stand while the histogram still gets [`Hist::bytes`]'s
    /// count-friendly base of 1, not the latency base).
    pub predict_batches: Mutex<Option<Hist>>,
}

impl ServerMetrics {
    pub fn inc(c: &AtomicU64) {
        c.fetch_add(1, Ordering::Relaxed);
    }

    pub fn get(c: &AtomicU64) -> u64 {
        c.load(Ordering::Relaxed)
    }

    /// Record one handled request under its normalized endpoint label.
    pub fn observe_request(&self, endpoint: &str, secs: f64) {
        let mut map = self.request_hists.lock().unwrap_or_else(|p| p.into_inner());
        map.entry(endpoint.to_string()).or_default().record(secs);
    }

    /// Snapshot of every endpoint histogram (label-sorted — BTreeMap
    /// order), for the Prometheus exposition and tests.
    pub fn endpoint_hists(&self) -> Vec<(String, Hist)> {
        let map = self.request_hists.lock().unwrap_or_else(|p| p.into_inner());
        map.iter().map(|(k, h)| (k.clone(), h.clone())).collect()
    }

    /// Record one task-endpoint predict call: `model` names what served
    /// it (`session:{name}` / `artifact:{name}`), `batch` how many
    /// points the call carried, `secs` the prediction latency.
    pub fn observe_predict(&self, model: &str, batch: usize, secs: f64) {
        let mut map = self.predict_hists.lock().unwrap_or_else(|p| p.into_inner());
        map.entry(model.to_string()).or_default().record(secs);
        drop(map);
        let mut b =
            self.predict_batches.lock().unwrap_or_else(|p| p.into_inner());
        b.get_or_insert_with(Hist::bytes).record(batch as f64);
    }

    /// Snapshot of the per-model predict-latency histograms
    /// (label-sorted).
    pub fn predict_hists(&self) -> Vec<(String, Hist)> {
        let map = self.predict_hists.lock().unwrap_or_else(|p| p.into_inner());
        map.iter().map(|(k, h)| (k.clone(), h.clone())).collect()
    }

    /// Snapshot of the batch-size histogram (empty until the first
    /// predict call).
    pub fn predict_batches(&self) -> Hist {
        self.predict_batches
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .clone()
            .unwrap_or_else(Hist::bytes)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("connections", Json::Num(Self::get(&self.connections) as f64)),
            ("requests", Json::Num(Self::get(&self.requests) as f64)),
            ("errors", Json::Num(Self::get(&self.errors) as f64)),
            (
                "rate_limited",
                Json::Num(Self::get(&self.rate_limited) as f64),
            ),
            (
                "rejected_overload",
                Json::Num(Self::get(&self.rejected_overload) as f64),
            ),
            (
                "sessions_created",
                Json::Num(Self::get(&self.sessions_created) as f64),
            ),
            (
                "sessions_finished",
                Json::Num(Self::get(&self.sessions_finished) as f64),
            ),
            (
                "snapshots_total",
                Json::Num(Self::get(&self.snapshots_total) as f64),
            ),
            (
                "queries_total",
                Json::Num(Self::get(&self.queries_total) as f64),
            ),
            (
                "artifacts_saved",
                Json::Num(Self::get(&self.artifacts_saved) as f64),
            ),
            (
                "artifacts_loaded",
                Json::Num(Self::get(&self.artifacts_loaded) as f64),
            ),
            (
                "artifact_queries",
                Json::Num(Self::get(&self.artifact_queries) as f64),
            ),
            (
                "tasks_fitted",
                Json::Num(Self::get(&self.tasks_fitted) as f64),
            ),
            (
                "task_cache_hits",
                Json::Num(Self::get(&self.task_cache_hits) as f64),
            ),
            (
                "task_predictions",
                Json::Num(Self::get(&self.task_predictions) as f64),
            ),
        ])
    }

    /// Every counter as `(prometheus_name, help, value)` triples, in
    /// the same order as [`to_json`](ServerMetrics::to_json) — the
    /// Prometheus page is generated from this list so the two renderings
    /// can never drift apart.
    pub fn counter_triples(&self) -> Vec<(&'static str, &'static str, u64)> {
        vec![
            (
                "oasis_connections_total",
                "Client connections accepted.",
                Self::get(&self.connections),
            ),
            (
                "oasis_requests_total",
                "HTTP requests handled.",
                Self::get(&self.requests),
            ),
            (
                "oasis_errors_total",
                "Requests answered with a 4xx/5xx status.",
                Self::get(&self.errors),
            ),
            (
                "oasis_rate_limited_total",
                "Requests answered 429 under the rate caps.",
                Self::get(&self.rate_limited),
            ),
            (
                "oasis_rejected_overload_total",
                "Connections shed 503 on a full accept queue.",
                Self::get(&self.rejected_overload),
            ),
            (
                "oasis_sessions_created_total",
                "Sampler sessions created.",
                Self::get(&self.sessions_created),
            ),
            (
                "oasis_sessions_finished_total",
                "Sampler sessions finished.",
                Self::get(&self.sessions_finished),
            ),
            (
                "oasis_snapshots_total",
                "Snapshots assembled.",
                Self::get(&self.snapshots_total),
            ),
            (
                "oasis_queries_total",
                "Out-of-sample queries answered from live sessions.",
                Self::get(&self.queries_total),
            ),
            (
                "oasis_artifacts_saved_total",
                "Session factorizations persisted to artifacts.",
                Self::get(&self.artifacts_saved),
            ),
            (
                "oasis_artifacts_loaded_total",
                "Stored artifacts hosted.",
                Self::get(&self.artifacts_loaded),
            ),
            (
                "oasis_artifact_queries_total",
                "Queries answered from loaded artifacts.",
                Self::get(&self.artifact_queries),
            ),
            (
                "oasis_tasks_fitted_total",
                "Downstream-task models fit.",
                Self::get(&self.tasks_fitted),
            ),
            (
                "oasis_task_cache_hits_total",
                "Task requests answered from a cached fitted model.",
                Self::get(&self.task_cache_hits),
            ),
            (
                "oasis_task_predictions_total",
                "Points predicted by the task endpoints.",
                Self::get(&self.task_predictions),
            ),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_render() {
        let m = ServerMetrics::default();
        ServerMetrics::inc(&m.requests);
        ServerMetrics::inc(&m.requests);
        let j = m.to_json();
        assert_eq!(j.get("requests").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("errors").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn counter_triples_cover_every_json_counter() {
        let m = ServerMetrics::default();
        let triples = m.counter_triples();
        let json_keys: Vec<String> = match m.to_json() {
            Json::Obj(o) => o.keys().cloned().collect(),
            _ => panic!("counters must render as an object"),
        };
        assert_eq!(triples.len(), json_keys.len());
        for key in &json_keys {
            // some JSON keys already carry the suffix (snapshots_total)
            let base = key.strip_suffix("_total").unwrap_or(key);
            assert!(
                triples
                    .iter()
                    .any(|(name, _, _)| *name == format!("oasis_{base}_total")),
                "JSON counter '{key}' missing from the Prometheus triples"
            );
        }
    }

    #[test]
    fn predict_histograms_accumulate_and_stay_out_of_counters() {
        let m = ServerMetrics::default();
        m.observe_predict("artifact:m1", 16, 0.002);
        m.observe_predict("artifact:m1", 1, 0.001);
        m.observe_predict("session:s1", 64, 0.004);
        let hists = m.predict_hists();
        assert_eq!(hists.len(), 2);
        assert_eq!(hists[0].0, "artifact:m1");
        assert_eq!(hists[0].1.count(), 2);
        let batches = m.predict_batches();
        assert_eq!(batches.count(), 3);
        assert_eq!(batches.max(), 64.0);
        // the counter JSON stays counters-only (see
        // counter_triples_cover_every_json_counter)
        assert!(m.to_json().get("predict").is_none());
    }

    #[test]
    fn request_histograms_accumulate_per_endpoint() {
        let m = ServerMetrics::default();
        m.observe_request("GET /healthz", 0.001);
        m.observe_request("GET /healthz", 0.002);
        m.observe_request("POST /sessions/{name}/step", 0.1);
        let hists = m.endpoint_hists();
        assert_eq!(hists.len(), 2);
        let (ref label, ref h) = hists[0];
        assert_eq!(label, "GET /healthz");
        assert_eq!(h.count(), 2);
        assert_eq!(hists[1].1.count(), 1);
    }
}
