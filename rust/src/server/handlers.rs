//! Endpoint dispatch: every route parses its payload, talks to the
//! [`Registry`](super::registry::Registry), and renders a JSON
//! [`Response`]. Errors are `{"error": …}` with a 4xx/5xx status; no
//! handler panics on user input (parsers validate before constructors
//! that `assert!`).

use super::http::{Request, Response};
use super::metrics::ServerMetrics;
use super::protocol;
use super::registry::{self, lock, SessionStats};
use super::ServerState;
use crate::util::json::Json;
use std::sync::Arc;

fn error(status: u16, msg: impl std::fmt::Display) -> Response {
    Response::json(
        status,
        Json::obj(vec![("error", Json::Str(msg.to_string()))]),
    )
}

/// Dispatch one request (see the protocol reference in [`crate::server`]).
pub fn route(state: &Arc<ServerState>, req: &Request) -> Response {
    ServerMetrics::inc(&state.metrics.requests);
    let segs = req.segments();
    let resp = match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => {
            Response::json(200, Json::obj(vec![("ok", Json::Bool(true))]))
        }
        ("GET", ["metrics"]) => metrics_report(state),
        ("GET", ["sessions"]) => list_sessions(state),
        ("POST", ["sessions"]) => create_session(state, req),
        ("GET", ["sessions", name]) => session_status(state, name),
        ("POST", ["sessions", name, "step"]) => step_session(state, name, req),
        ("GET" | "POST", ["sessions", name, "snapshot"]) => {
            snapshot_session(state, name, req)
        }
        ("POST", ["sessions", name, "query"]) => query_session(state, name, req),
        ("POST", ["sessions", name, "finish"])
        | ("DELETE", ["sessions", name]) => finish_session(state, name, req),
        ("POST", ["shutdown"]) => {
            state.request_stop();
            Response::json(200, Json::obj(vec![("stopping", Json::Bool(true))]))
        }
        _ => error(
            404,
            "no such endpoint (see the protocol reference in oasis::server)",
        ),
    };
    if resp.status >= 400 {
        ServerMetrics::inc(&state.metrics.errors);
    }
    resp
}

fn factor_elems(c: &crate::linalg::Mat, winv: &crate::linalg::Mat) -> usize {
    c.data.len().saturating_add(winv.data.len())
}

/// `?factors=1` refused for factor sets whose JSON rendering would dwarf
/// the matrices themselves (see [`protocol::MAX_FACTOR_ELEMS`]).
fn factors_too_large(c: &crate::linalg::Mat, winv: &crate::linalg::Mat) -> Response {
    error(
        400,
        format!(
            "factors=1 refused: {} factor elements exceed the cap of {} — \
             fetch indices only, or grow the approximation in smaller pieces",
            factor_elems(c, winv),
            protocol::MAX_FACTOR_ELEMS
        ),
    )
}

fn stats_json(name: &str, st: &SessionStats) -> Json {
    let mut fields = vec![
        ("name", Json::Str(name.to_string())),
        ("method", Json::Str(st.method.clone())),
        ("n", Json::Num(st.n as f64)),
        ("k", Json::Num(st.k as f64)),
        ("busy", Json::Bool(st.busy)),
        ("steps_done", Json::Num(st.steps_done as f64)),
        ("error_estimate", protocol::opt_num(st.error_estimate)),
        ("selection_secs", Json::Num(st.selection_secs)),
        ("step_latency", st.step_latency.to_json()),
    ];
    if let Some(r) = st.stop {
        fields.push(("stop", Json::Str(r.as_str().to_string())));
    }
    if let Some(f) = &st.failed {
        fields.push(("failed", Json::Str(f.clone())));
    }
    Json::obj(fields)
}

fn create_session(state: &Arc<ServerState>, req: &Request) -> Response {
    let parsed = match protocol::parse_create(&req.body_str()) {
        Ok(p) => p,
        Err(e) => return error(400, e),
    };
    // pre-check for a clean 409; a lost creation race still errors safely
    let duplicate = parsed
        .name
        .as_deref()
        .map(|n| state.registry.get(n).is_some())
        .unwrap_or(false);
    match state.registry.create(parsed) {
        Ok(handle) => {
            ServerMetrics::inc(&state.metrics.sessions_created);
            let st = lock(&handle.shared.stats).clone();
            Response::json(
                200,
                Json::obj(vec![
                    ("name", Json::Str(handle.name.clone())),
                    ("method", Json::Str(st.method)),
                    ("n", Json::Num(st.n as f64)),
                    ("dim", Json::Num(handle.dataset.dim() as f64)),
                    ("k", Json::Num(st.k as f64)),
                    ("error_estimate", protocol::opt_num(st.error_estimate)),
                ]),
            )
        }
        Err(e) => error(if duplicate { 409 } else { 400 }, e),
    }
}

fn list_sessions(state: &Arc<ServerState>) -> Response {
    let sessions: Vec<Json> = state
        .registry
        .list()
        .into_iter()
        .map(|(name, shared)| stats_json(&name, &lock(&shared.stats).clone()))
        .collect();
    Response::json(200, Json::obj(vec![("sessions", Json::Arr(sessions))]))
}

fn session_status(state: &Arc<ServerState>, name: &str) -> Response {
    match state.registry.get(name) {
        None => error(404, format!("no session '{name}'")),
        Some(h) => {
            let st = lock(&h.shared.stats).clone();
            Response::json(200, stats_json(&h.name, &st))
        }
    }
}

fn step_session(state: &Arc<ServerState>, name: &str, req: &Request) -> Response {
    let h = match state.registry.get(name) {
        None => return error(404, format!("no session '{name}'")),
        Some(h) => h,
    };
    let sreq = match protocol::parse_step(&req.body_str()) {
        Ok(s) => s,
        Err(e) => return error(400, e),
    };
    if sreq.background {
        return match registry::step_background(&h, sreq.steps, sreq.rule) {
            Ok(()) => Response::json(
                202,
                Json::obj(vec![
                    ("accepted", Json::Bool(true)),
                    ("name", Json::Str(h.name.clone())),
                    ("steps", Json::Num(sreq.steps as f64)),
                ]),
            ),
            Err(e) => error(410, e),
        };
    }
    let result = registry::step_sync(&h, sreq.steps, sreq.rule);
    match result {
        Ok(rep) => {
            let mut fields = vec![
                ("name", Json::Str(h.name.clone())),
                ("k", Json::Num(rep.k as f64)),
                ("stepped", Json::Num(rep.stepped as f64)),
                ("error_estimate", protocol::opt_num(rep.error_estimate)),
                ("secs", Json::Num(rep.secs)),
            ];
            if let Some(r) = rep.stop {
                fields.push(("stop", Json::Str(r.as_str().to_string())));
            }
            Response::json(200, Json::obj(fields))
        }
        Err(e) => {
            // a session finished by a concurrent request is the client's
            // race (410, like the background path), not a server fault
            let gone = lock(&h.shared.stats).finished;
            error(if gone { 410 } else { 500 }, e)
        }
    }
}

fn snapshot_session(
    state: &Arc<ServerState>,
    name: &str,
    req: &Request,
) -> Response {
    let h = match state.registry.get(name) {
        None => return error(404, format!("no session '{name}'")),
        Some(h) => h,
    };
    let body = match protocol::parse_body(&req.body_str()) {
        Ok(b) => b,
        Err(e) => return error(400, e),
    };
    let factors = req.flag(&body, "factors");
    // `cached=true` reuses the query cache; the default is a fresh gather
    let cached = req.flag(&body, "cached");
    match registry::ensure_snapshot(&h, !cached) {
        Ok(snap) => {
            if factors && factor_elems(&snap.c, &snap.winv) > protocol::MAX_FACTOR_ELEMS
            {
                return factors_too_large(&snap.c, &snap.winv);
            }
            ServerMetrics::inc(&state.metrics.snapshots_total);
            let st = lock(&h.shared.stats).clone();
            let mut fields = vec![
                ("name", Json::Str(h.name.clone())),
                ("n", Json::Num(snap.n() as f64)),
                ("k", Json::Num(snap.k() as f64)),
                ("indices", protocol::usize_arr(&snap.indices)),
                ("error_estimate", protocol::opt_num(st.error_estimate)),
                ("selection_secs", Json::Num(snap.selection_secs)),
            ];
            if factors {
                fields.push(("c", protocol::mat_json(&snap.c)));
                fields.push(("winv", protocol::mat_json(&snap.winv)));
            }
            Response::json(200, Json::obj(fields))
        }
        Err(e) => error(500, e),
    }
}

fn query_session(state: &Arc<ServerState>, name: &str, req: &Request) -> Response {
    let h = match state.registry.get(name) {
        None => return error(404, format!("no session '{name}'")),
        Some(h) => h,
    };
    let q = match protocol::parse_query(&req.body_str()) {
        Ok(q) => q,
        Err(e) => return error(400, e),
    };
    let dim = h.dataset.dim();
    for (i, p) in q.points.iter().enumerate() {
        if p.len() != dim {
            return error(
                400,
                format!(
                    "query point {i} has dimension {} but the dataset has {dim}",
                    p.len()
                ),
            );
        }
    }
    let snap = match registry::ensure_snapshot(&h, q.refresh) {
        Ok(s) => s,
        Err(e) => return error(500, e),
    };
    let n = snap.n();
    for &t in &q.targets {
        if t >= n {
            return error(400, format!("target index {t} out of range (n = {n})"));
        }
    }
    let mut results = Vec::with_capacity(q.points.len());
    for p in &q.points {
        // b = k(z, x_Λ): only the selected points are evaluated
        let b: Vec<f64> = snap
            .indices
            .iter()
            .map(|&j| h.kernel.eval(p, h.dataset.point(j)))
            .collect();
        let w = snap.extension_weights(&b);
        let mut fields = vec![("weights", protocol::num_arr(&w))];
        if !q.targets.is_empty() {
            let vals: Vec<f64> =
                q.targets.iter().map(|&t| snap.extend_entry(&w, t)).collect();
            fields.push(("kernel", protocol::num_arr(&vals)));
        }
        results.push(Json::obj(fields));
    }
    ServerMetrics::inc(&state.metrics.queries_total);
    Response::json(
        200,
        Json::obj(vec![
            ("name", Json::Str(h.name.clone())),
            ("snapshot_k", Json::Num(snap.k() as f64)),
            ("results", Json::Arr(results)),
        ]),
    )
}

fn finish_session(state: &Arc<ServerState>, name: &str, req: &Request) -> Response {
    // parse before removing: a malformed body must not evict the session
    let body = match protocol::parse_body(&req.body_str()) {
        Ok(b) => b,
        Err(e) => return error(400, e),
    };
    let factors = req.flag(&body, "factors");
    let (h, join) = match state.registry.remove(name) {
        None => return error(404, format!("no session '{name}'")),
        Some(x) => x,
    };
    let res = registry::finish(&h);
    let _ = join.join();
    match res {
        Ok(approx) => {
            // the session is already evicted; degrade to indices-only
            // rather than building an over-cap JSON tree
            let factors = factors
                && factor_elems(&approx.c, &approx.winv)
                    <= protocol::MAX_FACTOR_ELEMS;
            ServerMetrics::inc(&state.metrics.sessions_finished);
            let mut fields = vec![
                ("name", Json::Str(h.name.clone())),
                ("final", Json::Bool(true)),
                ("n", Json::Num(approx.n() as f64)),
                ("k", Json::Num(approx.k() as f64)),
                ("indices", protocol::usize_arr(&approx.indices)),
                ("selection_secs", Json::Num(approx.selection_secs)),
            ];
            if factors {
                fields.push(("c", protocol::mat_json(&approx.c)));
                fields.push(("winv", protocol::mat_json(&approx.winv)));
            }
            Response::json(200, Json::obj(fields))
        }
        Err(e) => error(500, e),
    }
}

fn metrics_report(state: &Arc<ServerState>) -> Response {
    let sessions: Vec<Json> = state
        .registry
        .list()
        .into_iter()
        .map(|(name, shared)| stats_json(&name, &lock(&shared.stats).clone()))
        .collect();
    Response::json(
        200,
        Json::obj(vec![
            (
                "uptime_secs",
                Json::Num(state.started.elapsed().as_secs_f64()),
            ),
            ("server", state.metrics.to_json()),
            ("sessions", Json::Arr(sessions)),
        ]),
    )
}
