//! Endpoint dispatch: every route parses its payload, talks to the
//! [`Registry`](super::registry::Registry), and renders a JSON
//! [`Response`]. Errors are `{"error": …}` with a 4xx/5xx status; no
//! handler panics on user input (parsers validate before constructors
//! that `assert!`).

use super::http::{Request, Response};
use super::metrics::ServerMetrics;
use super::protocol;
use super::registry::{self, lock, SessionStats};
use super::ServerState;
use crate::util::json::Json;
use std::sync::Arc;

fn error(status: u16, msg: impl std::fmt::Display) -> Response {
    Response::json(
        status,
        Json::obj(vec![("error", Json::Str(msg.to_string()))]),
    )
}

/// Dispatch one request (see the protocol reference in [`crate::server`]).
pub fn route(state: &Arc<ServerState>, req: &Request) -> Response {
    ServerMetrics::inc(&state.metrics.requests);
    let segs = req.segments();
    let resp = match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => Response::json(
            200,
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "uptime_secs",
                    Json::Num(state.started.elapsed().as_secs_f64()),
                ),
                ("start_time_unix_secs", Json::Num(state.start_unix_secs)),
                ("version", Json::Str(env!("CARGO_PKG_VERSION").into())),
            ]),
        ),
        ("GET", ["metrics"]) => {
            if wants_prometheus(req) {
                metrics_prometheus(state)
            } else {
                metrics_report(state)
            }
        }
        ("GET", ["sessions"]) => list_sessions(state),
        ("POST", ["sessions"]) => create_session(state, req),
        ("GET", ["sessions", name]) => session_status(state, name),
        ("GET", ["sessions", name, "trajectory"]) => {
            session_trajectory(state, name)
        }
        ("POST", ["sessions", name, "step"]) => step_session(state, name, req),
        ("GET" | "POST", ["sessions", name, "snapshot"]) => {
            snapshot_session(state, name, req)
        }
        ("POST", ["sessions", name, "query"]) => query_session(state, name, req),
        ("POST", ["sessions", name, "task"]) => task_session(state, name, req),
        ("POST", ["sessions", name, "save"]) => save_session(state, name, req),
        ("POST", ["sessions", name, "finish"])
        | ("DELETE", ["sessions", name]) => finish_session(state, name, req),
        ("POST", ["artifacts", "load"]) => load_artifact(state, req),
        ("GET", ["artifacts"]) => list_artifacts(state),
        ("GET", ["artifacts", name]) => artifact_status(state, name),
        ("POST", ["artifacts", name, "query"]) => query_artifact(state, name, req),
        ("POST", ["artifacts", name, "task"]) => task_artifact(state, name, req),
        ("DELETE", ["artifacts", name]) => unload_artifact(state, name),
        ("GET", ["debug", "trace"]) => debug_trace_get(req),
        ("POST", ["debug", "trace"]) => debug_trace_post(req),
        ("POST", ["shutdown"]) => {
            state.request_stop();
            Response::json(200, Json::obj(vec![("stopping", Json::Bool(true))]))
        }
        _ => error(
            404,
            "no such endpoint (see the protocol reference in oasis::server)",
        ),
    };
    if resp.status >= 400 {
        ServerMetrics::inc(&state.metrics.errors);
    }
    resp
}

/// Normalized endpoint label for the request-duration histograms:
/// session/artifact names collapse to `{name}` placeholders and unknown
/// paths collapse to `other`, so the label set (and with it the
/// Prometheus series count) stays bounded no matter what clients send.
pub fn endpoint_label(req: &Request) -> String {
    const SESSION_VERBS: [&str; 7] =
        ["step", "snapshot", "query", "task", "save", "finish", "trajectory"];
    const ARTIFACT_VERBS: [&str; 2] = ["query", "task"];
    let segs = req.segments();
    let path: String = match segs.as_slice() {
        ["healthz"] => "/healthz".into(),
        ["metrics"] => "/metrics".into(),
        ["sessions"] => "/sessions".into(),
        ["sessions", _] => "/sessions/{name}".into(),
        ["sessions", _, v] if SESSION_VERBS.contains(v) => {
            format!("/sessions/{{name}}/{v}")
        }
        ["artifacts", "load"] => "/artifacts/load".into(),
        ["artifacts"] => "/artifacts".into(),
        ["artifacts", _] => "/artifacts/{name}".into(),
        ["artifacts", _, v] if ARTIFACT_VERBS.contains(v) => {
            format!("/artifacts/{{name}}/{v}")
        }
        ["debug", "trace"] => "/debug/trace".into(),
        ["shutdown"] => "/shutdown".into(),
        _ => "other".into(),
    };
    format!("{} {path}", req.method)
}

/// `GET /metrics` content negotiation: the `?format=prometheus` query
/// parameter wins; otherwise an `Accept` header asking for `text/plain`
/// (what Prometheus sends) or `openmetrics`. JSON stays the default.
fn wants_prometheus(req: &Request) -> bool {
    if let Some(f) = req.query.get("format") {
        return f == "prometheus";
    }
    req.headers
        .get("accept")
        .map(|a| {
            let a = a.to_ascii_lowercase();
            a.contains("text/plain") || a.contains("openmetrics")
        })
        .unwrap_or(false)
}

fn factor_elems(c: &crate::linalg::Mat, winv: &crate::linalg::Mat) -> usize {
    c.data.len().saturating_add(winv.data.len())
}

/// `?factors=1` refused for factor sets whose JSON rendering would dwarf
/// the matrices themselves (see [`protocol::MAX_FACTOR_ELEMS`]).
fn factors_too_large(c: &crate::linalg::Mat, winv: &crate::linalg::Mat) -> Response {
    error(
        400,
        format!(
            "factors=1 refused: {} factor elements exceed the cap of {} — \
             fetch indices only, or grow the approximation in smaller pieces",
            factor_elems(c, winv),
            protocol::MAX_FACTOR_ELEMS
        ),
    )
}

fn stats_json(name: &str, st: &SessionStats) -> Json {
    let mut fields = vec![
        ("name", Json::Str(name.to_string())),
        ("method", Json::Str(st.method.clone())),
        ("n", Json::Num(st.n as f64)),
        ("k", Json::Num(st.k as f64)),
        ("busy", Json::Bool(st.busy)),
        ("steps_done", Json::Num(st.steps_done as f64)),
        ("error_estimate", protocol::opt_num(st.error_estimate)),
        ("best_score", protocol::opt_num(st.best_score)),
        ("selection_secs", Json::Num(st.selection_secs)),
        ("step_latency", st.step_latency.to_json()),
    ];
    if let Some(r) = st.stop {
        fields.push(("stop", Json::Str(r.as_str().to_string())));
    }
    if let Some(f) = &st.failed {
        fields.push(("failed", Json::Str(f.clone())));
    }
    if let Some(w) = &st.workers {
        fields.push(("workers", w.clone()));
    }
    Json::obj(fields)
}

/// Upper bound on the ring capacity `POST /debug/trace` will accept —
/// one OwnedEvent is a few hundred bytes, so 2^20 events caps the live
/// recorder's memory at a few hundred MB even against a hostile client.
const MAX_TRACE_CAPACITY: usize = 1 << 20;

/// `POST /debug/trace {"enable": bool, "capacity": n}` — toggle the
/// process-wide trace recorder at runtime. Enabling (re)sizes and clears
/// the ring; disabling stops recording but leaves buffered events
/// drainable by a final GET.
fn debug_trace_post(req: &Request) -> Response {
    use crate::obs::trace;
    let body = match protocol::parse_body(&req.body_str()) {
        Ok(b) => b,
        Err(e) => return error(400, e),
    };
    let enable = body.get("enable").and_then(Json::as_bool).unwrap_or(true);
    let capacity = body
        .get("capacity")
        .and_then(Json::as_usize)
        .unwrap_or(trace::DEFAULT_CAPACITY)
        .clamp(1, MAX_TRACE_CAPACITY);
    if enable {
        trace::enable_with_capacity(capacity);
    } else {
        trace::disable();
    }
    Response::json(
        200,
        Json::obj(vec![
            ("enabled", Json::Bool(trace::enabled())),
            ("capacity", Json::Num(capacity as f64)),
        ]),
    )
}

/// `GET /debug/trace` — drain the recorder's buffered spans and serve
/// them as a Chrome `trace_event` JSON document (or per-line JSON with
/// `?format=jsonl`). Draining is destructive: each event is served
/// exactly once, so a scraper can poll without re-downloading history.
fn debug_trace_get(req: &Request) -> Response {
    use crate::obs::trace;
    let track = trace::drain().into_track(1, "server");
    if req.query.get("format").map(String::as_str) == Some("jsonl") {
        Response::text(200, "application/jsonl", trace::merged_jsonl(&[track]))
    } else {
        Response::json(200, trace::merged_chrome_json(&[track]))
    }
}

/// `GET /sessions/{name}/trajectory` — the session's convergence
/// trajectory: one point per adaptive selection (bounded ring of the
/// most recent [`registry::TRAJECTORY_CAP`]), oldest first.
fn session_trajectory(state: &Arc<ServerState>, name: &str) -> Response {
    let h = match state.registry.get(name) {
        None => return error(404, format!("no session '{name}'")),
        Some(h) => h,
    };
    let t = lock(&h.shared.trajectory);
    let points: Vec<Json> = t.points.iter().map(|p| p.to_json()).collect();
    Response::json(
        200,
        Json::obj(vec![
            ("name", Json::Str(h.name.clone())),
            ("count", Json::Num(points.len() as f64)),
            ("dropped", Json::Num(t.dropped as f64)),
            ("capacity", Json::Num(registry::TRAJECTORY_CAP as f64)),
            ("points", Json::Arr(points)),
        ]),
    )
}

fn create_session(state: &Arc<ServerState>, req: &Request) -> Response {
    // file-backed dataset paths are resolved under --fs-root inside the
    // parser itself (the `client` field keeps the raw spelling for
    // provenance), so an unresolved path cannot reach the registry
    let parsed =
        match protocol::parse_create(&req.body_str(), &state.config.fs_root) {
            Ok(p) => p,
            Err(e) => return error(400, e),
        };
    // pre-check for a clean 409; a lost creation race still errors safely
    let duplicate = parsed
        .name
        .as_deref()
        .map(|n| state.registry.get(n).is_some())
        .unwrap_or(false);
    match state.registry.create(parsed) {
        Ok(handle) => {
            ServerMetrics::inc(&state.metrics.sessions_created);
            let st = lock(&handle.shared.stats).clone();
            Response::json(
                200,
                Json::obj(vec![
                    ("name", Json::Str(handle.name.clone())),
                    ("method", Json::Str(st.method)),
                    ("n", Json::Num(st.n as f64)),
                    ("dim", Json::Num(handle.points.dim() as f64)),
                    ("k", Json::Num(st.k as f64)),
                    ("error_estimate", protocol::opt_num(st.error_estimate)),
                ]),
            )
        }
        Err(e) => error(if duplicate { 409 } else { 400 }, e),
    }
}

fn list_sessions(state: &Arc<ServerState>) -> Response {
    let sessions: Vec<Json> = state
        .registry
        .list()
        .into_iter()
        .map(|(name, shared)| stats_json(&name, &lock(&shared.stats).clone()))
        .collect();
    Response::json(200, Json::obj(vec![("sessions", Json::Arr(sessions))]))
}

fn session_status(state: &Arc<ServerState>, name: &str) -> Response {
    match state.registry.get(name) {
        None => error(404, format!("no session '{name}'")),
        Some(h) => {
            let st = lock(&h.shared.stats).clone();
            Response::json(200, stats_json(&h.name, &st))
        }
    }
}

fn step_session(state: &Arc<ServerState>, name: &str, req: &Request) -> Response {
    let h = match state.registry.get(name) {
        None => return error(404, format!("no session '{name}'")),
        Some(h) => h,
    };
    let sreq = match protocol::parse_step(&req.body_str()) {
        Ok(s) => s,
        Err(e) => return error(400, e),
    };
    if sreq.background {
        return match registry::step_background(&h, sreq.steps, sreq.rule) {
            Ok(()) => Response::json(
                202,
                Json::obj(vec![
                    ("accepted", Json::Bool(true)),
                    ("name", Json::Str(h.name.clone())),
                    ("steps", Json::Num(sreq.steps as f64)),
                ]),
            ),
            Err(e) => error(410, e),
        };
    }
    let result = registry::step_sync(&h, sreq.steps, sreq.rule);
    match result {
        Ok(rep) => {
            let mut fields = vec![
                ("name", Json::Str(h.name.clone())),
                ("k", Json::Num(rep.k as f64)),
                ("stepped", Json::Num(rep.stepped as f64)),
                ("error_estimate", protocol::opt_num(rep.error_estimate)),
                ("secs", Json::Num(rep.secs)),
            ];
            if let Some(r) = rep.stop {
                fields.push(("stop", Json::Str(r.as_str().to_string())));
            }
            Response::json(200, Json::obj(fields))
        }
        Err(e) => {
            // a session finished by a concurrent request is the client's
            // race (410, like the background path), not a server fault
            let gone = lock(&h.shared.stats).finished;
            error(if gone { 410 } else { 500 }, e)
        }
    }
}

fn snapshot_session(
    state: &Arc<ServerState>,
    name: &str,
    req: &Request,
) -> Response {
    let h = match state.registry.get(name) {
        None => return error(404, format!("no session '{name}'")),
        Some(h) => h,
    };
    let body = match protocol::parse_body(&req.body_str()) {
        Ok(b) => b,
        Err(e) => return error(400, e),
    };
    let factors = req.flag(&body, "factors");
    // `cached=true` reuses the query cache; the default is a fresh gather
    let cached = req.flag(&body, "cached");
    match registry::ensure_snapshot(&h, !cached) {
        Ok(snap) => {
            if factors && factor_elems(&snap.c, &snap.winv) > protocol::MAX_FACTOR_ELEMS
            {
                return factors_too_large(&snap.c, &snap.winv);
            }
            ServerMetrics::inc(&state.metrics.snapshots_total);
            let st = lock(&h.shared.stats).clone();
            let mut fields = vec![
                ("name", Json::Str(h.name.clone())),
                ("n", Json::Num(snap.n() as f64)),
                ("k", Json::Num(snap.k() as f64)),
                ("indices", protocol::usize_arr(&snap.indices)),
                ("error_estimate", protocol::opt_num(st.error_estimate)),
                ("selection_secs", Json::Num(snap.selection_secs)),
            ];
            if factors {
                fields.push(("c", protocol::mat_json(&snap.c)));
                fields.push(("winv", protocol::mat_json(&snap.winv)));
            }
            Response::json(200, Json::obj(fields))
        }
        Err(e) => error(500, e),
    }
}

fn query_session(state: &Arc<ServerState>, name: &str, req: &Request) -> Response {
    let h = match state.registry.get(name) {
        None => return error(404, format!("no session '{name}'")),
        Some(h) => h,
    };
    let q = match protocol::parse_query(&req.body_str()) {
        Ok(q) => q,
        Err(e) => return error(400, e),
    };
    let dim = h.points.dim();
    for (i, p) in q.points.iter().enumerate() {
        if p.len() != dim {
            return error(
                400,
                format!(
                    "query point {i} has dimension {} but the dataset has {dim}",
                    p.len()
                ),
            );
        }
    }
    let snap = match registry::ensure_snapshot(&h, q.refresh) {
        Ok(s) => s,
        Err(e) => return error(500, e),
    };
    let n = snap.n();
    for &t in &q.targets {
        if t >= n {
            return error(400, format!("target index {t} out of range (n = {n})"));
        }
    }
    let mut results = Vec::with_capacity(q.points.len());
    for p in &q.points {
        // b = k(z, x_Λ): only the selected points are evaluated (via the
        // dataset, or the shard-read selected-points mirror)
        let b = match h.points.kernel_row(&*h.kernel, p, &snap.indices) {
            Ok(b) => b,
            Err(e) => return error(500, e),
        };
        let w = snap.extension_weights(&b);
        let mut fields = vec![("weights", protocol::num_arr(&w))];
        if !q.targets.is_empty() {
            let vals: Vec<f64> =
                q.targets.iter().map(|&t| snap.extend_entry(&w, t)).collect();
            fields.push(("kernel", protocol::num_arr(&vals)));
        }
        results.push(Json::obj(fields));
    }
    ServerMetrics::inc(&state.metrics.queries_total);
    Response::json(
        200,
        Json::obj(vec![
            ("name", Json::Str(h.name.clone())),
            ("snapshot_k", Json::Num(snap.k() as f64)),
            ("results", Json::Arr(results)),
        ]),
    )
}

fn finish_session(state: &Arc<ServerState>, name: &str, req: &Request) -> Response {
    // parse before removing: a malformed body must not evict the session
    let body = match protocol::parse_body(&req.body_str()) {
        Ok(b) => b,
        Err(e) => return error(400, e),
    };
    let factors = req.flag(&body, "factors");
    let (h, join) = match state.registry.remove(name) {
        None => return error(404, format!("no session '{name}'")),
        Some(x) => x,
    };
    let res = registry::finish(&h);
    let _ = join.join();
    match res {
        Ok(approx) => {
            // the session is already evicted; degrade to indices-only
            // rather than building an over-cap JSON tree
            let factors = factors
                && factor_elems(&approx.c, &approx.winv)
                    <= protocol::MAX_FACTOR_ELEMS;
            ServerMetrics::inc(&state.metrics.sessions_finished);
            let mut fields = vec![
                ("name", Json::Str(h.name.clone())),
                ("final", Json::Bool(true)),
                ("n", Json::Num(approx.n() as f64)),
                ("k", Json::Num(approx.k() as f64)),
                ("indices", protocol::usize_arr(&approx.indices)),
                ("selection_secs", Json::Num(approx.selection_secs)),
            ];
            if factors {
                fields.push(("c", protocol::mat_json(&approx.c)));
                fields.push(("winv", protocol::mat_json(&approx.winv)));
            }
            Response::json(200, Json::obj(fields))
        }
        Err(e) => error(500, e),
    }
}

/// Resolve a task request into a validated
/// [`TaskConfig`](crate::tasks::TaskConfig): inline labels pass through,
/// file labels load under the serving caps through the engine's
/// resolver (the same path the CLI's `--labels` takes).
fn resolve_task_config(
    t: &protocol::TaskRequest,
) -> crate::Result<crate::tasks::TaskConfig> {
    use crate::engine::{LabelsSpec, SessionBuilder, TaskSpec};
    let labels = match &t.labels {
        None => None,
        Some(protocol::TaskLabels::Inline(v)) => Some(v.clone()),
        Some(protocol::TaskLabels::File { label, path, cols }) => {
            let spec = TaskSpec {
                kind: t.kind,
                ridge: t.ridge,
                components: t.components,
                clusters: t.clusters,
                seed: t.seed,
                labels: Some(LabelsSpec {
                    label: label.clone(),
                    path: path.clone(),
                    cols: cols.clone(),
                }),
            };
            return SessionBuilder::with_limits(protocol::serving_load_limits())
                .resolve_task(&spec);
        }
    };
    let cfg = crate::tasks::TaskConfig {
        kind: t.kind,
        ridge: t.ridge,
        components: t.components,
        clusters: t.clusters,
        seed: t.seed,
        labels,
    };
    cfg.validate()?;
    Ok(cfg)
}

/// Canonical cache key of a task config at snapshot size k: every
/// parameter the fit reads, with labels reduced to an FNV-1a 64 over
/// their bit patterns.
fn task_cache_key(cfg: &crate::tasks::TaskConfig, k: usize) -> String {
    let labels_fnv = cfg
        .labels
        .as_ref()
        .map(|cols| {
            let elems: usize = cols.iter().map(Vec::len).sum();
            let mut bytes = Vec::with_capacity(elems * 8 + cols.len() * 8);
            for col in cols {
                // column lengths delimit, so [[a,b],[c]] ≠ [[a],[b,c]]
                bytes.extend_from_slice(&(col.len() as u64).to_le_bytes());
                for v in col {
                    bytes.extend_from_slice(&v.to_bits().to_le_bytes());
                }
            }
            crate::util::framing::fnv1a64(&bytes)
        })
        .unwrap_or(0);
    format!(
        "{}|{:016x}|{}|{}|{}|{:016x}|k={k}",
        cfg.kind.as_str(),
        cfg.ridge.to_bits(),
        cfg.components,
        cfg.clusters,
        cfg.seed,
        labels_fnv
    )
}

/// Fit through the registry cache: an identical key reuses the cached
/// model (the common serve pattern — fit once, predict many); anything
/// else fits fresh and replaces the cache entry. Returns
/// `(model, was_cached)`.
fn fit_with_cache(
    cache: &std::sync::Mutex<Option<registry::CachedTask>>,
    approx: &crate::nystrom::NystromApprox,
    cfg: &crate::tasks::TaskConfig,
    key: String,
) -> crate::Result<(Arc<crate::tasks::FittedTask>, bool)> {
    if let Some(c) = lock(cache).as_ref() {
        // the key hashes the labels; compare them outright so a hash
        // collision can never serve a model fit to different labels
        if c.key == key && c.labels == cfg.labels {
            return Ok((c.model.clone(), true));
        }
    }
    let fit = crate::tasks::FittedTask::fit(approx, cfg)?;
    let model = Arc::new(fit.model);
    *lock(cache) = Some(registry::CachedTask {
        key,
        labels: cfg.labels.clone(),
        model: model.clone(),
    });
    Ok((model, false))
}

/// Run (and time) a task request's predictions — one landmark-block
/// kernel evaluation plus one blocked B×k product, through the f64 path
/// or the request's opt-in f32 path — and record the predict metrics
/// (batch size + per-model latency) under `model_label`.
fn run_predict(
    state: &Arc<ServerState>,
    model_label: &str,
    model: &crate::tasks::FittedTask,
    kernel: &dyn crate::kernels::Kernel,
    selected: &crate::data::Dataset,
    treq: &protocol::TaskRequest,
) -> crate::Result<crate::tasks::TaskPrediction> {
    let t0 = std::time::Instant::now();
    let p = if treq.f32_predict {
        model.predict_f32(kernel, selected, &treq.predict)?
    } else {
        model.predict(kernel, selected, &treq.predict)?
    };
    state.metrics.task_predictions.fetch_add(
        treq.predict.len() as u64,
        std::sync::atomic::Ordering::Relaxed,
    );
    state.metrics.observe_predict(
        model_label,
        treq.predict.len(),
        t0.elapsed().as_secs_f64(),
    );
    Ok(p)
}

/// Render a task response: the model's fit summary plus serving fields
/// and (when requested) the predictions — the `"predictions"` value is
/// rendered by the same code as the CLI's, so the two are
/// byte-identical for the same model and points.
fn task_response(
    name: &str,
    model: &crate::tasks::FittedTask,
    model_source: &str,
    predictions: Option<&crate::tasks::TaskPrediction>,
) -> Response {
    let mut fields = match model.summary_json() {
        Json::Obj(m) => m,
        _ => Default::default(),
    };
    fields.insert("name".into(), Json::Str(name.to_string()));
    fields.insert("model".into(), Json::Str(model_source.to_string()));
    if let Some(p) = predictions {
        fields.insert("predictions".into(), p.to_json());
    }
    Response::json(200, Json::Obj(fields))
}

/// Fit (or reuse) a downstream task on a live session's current
/// snapshot and predict for the request's points
/// (`POST /sessions/{name}/task`).
fn task_session(state: &Arc<ServerState>, name: &str, req: &Request) -> Response {
    let h = match state.registry.get(name) {
        None => return error(404, format!("no session '{name}'")),
        Some(h) => h,
    };
    let treq = match protocol::parse_task(&req.body_str(), &state.config.fs_root) {
        Ok(t) => t,
        Err(e) => return error(400, e),
    };
    let dim = h.points.dim();
    for (i, p) in treq.predict.iter().enumerate() {
        if p.len() != dim {
            return error(
                400,
                format!(
                    "predict point {i} has dimension {} but the dataset has {dim}",
                    p.len()
                ),
            );
        }
    }
    // fit-once-predict-many: a krr request without labels reuses the
    // session's most recently fitted krr model as-is (its ridge and fit
    // k), so predict traffic does not re-ship — or re-load — the label
    // set on every call. 400 when nothing was fitted yet.
    let label_free_krr =
        treq.kind == crate::tasks::TaskKind::Krr && treq.labels.is_none();
    let (model, cached) = if label_free_krr {
        match lock(&h.shared.task_cache)
            .as_ref()
            .filter(|c| c.model.kind() == crate::tasks::TaskKind::Krr)
            .map(|c| c.model.clone())
        {
            Some(m) => (m, true),
            None => {
                return error(
                    400,
                    "krr needs 'labels' or 'labels_file' (a later request \
                     may omit them to reuse the fitted model)",
                )
            }
        }
    } else {
        let cfg = match resolve_task_config(&treq) {
            Ok(c) => c,
            Err(e) => return error(400, e),
        };
        let snap = match registry::ensure_snapshot(&h, treq.refresh) {
            Ok(s) => s,
            Err(e) => return error(500, e),
        };
        let key = task_cache_key(&cfg, snap.k());
        match fit_with_cache(&h.shared.task_cache, &snap, &cfg, key) {
            Ok(x) => x,
            Err(e) => return error(400, e),
        }
    };
    ServerMetrics::inc(if cached {
        &state.metrics.task_cache_hits
    } else {
        &state.metrics.tasks_fitted
    });
    let predictions = if treq.predict.is_empty() {
        None
    } else {
        // the model's landmarks are the first k() selected indices —
        // selection is append-only, so a (possibly newer) snapshot's
        // prefix is exactly the fit-time index set
        let snap = match registry::ensure_snapshot(&h, false) {
            Ok(s) => s,
            Err(e) => return error(500, e),
        };
        if snap.indices.len() < model.k() {
            return error(
                500,
                "session snapshot is older than the fitted model — retry",
            );
        }
        let selected =
            match h.points.selected_dataset(&snap.indices[..model.k()]) {
                Ok(d) => d,
                Err(e) => return error(500, e),
            };
        let label = format!("session:{}", h.name);
        match run_predict(state, &label, &model, &*h.kernel, &selected, &treq)
        {
            Ok(p) => Some(p),
            Err(e) => return error(400, e),
        }
    };
    task_response(
        &h.name,
        &model,
        if cached { "cached" } else { "fitted" },
        predictions.as_ref(),
    )
}

/// Fit (or reuse) a downstream task on a loaded artifact — dataset-free
/// (`POST /artifacts/{name}/task`). A krr request without labels falls
/// back to the model stored in the artifact's task section, if any.
fn task_artifact(state: &Arc<ServerState>, name: &str, req: &Request) -> Response {
    let h = match state.artifacts.get(name) {
        None => return error(404, format!("no artifact '{name}'")),
        Some(h) => h,
    };
    let treq = match protocol::parse_task(&req.body_str(), &state.config.fs_root) {
        Ok(t) => t,
        Err(e) => return error(400, e),
    };
    let dim = h.artifact.dim();
    for (i, p) in treq.predict.iter().enumerate() {
        if p.len() != dim {
            return error(
                400,
                format!(
                    "predict point {i} has dimension {} but the artifact \
                     stores dimension {dim}",
                    p.len()
                ),
            );
        }
    }
    let stored_fallback = treq.kind == crate::tasks::TaskKind::Krr
        && treq.labels.is_none();
    let (model, source) = if stored_fallback {
        match &h.artifact.task {
            Some(m @ crate::tasks::FittedTask::Krr(_)) => {
                (Arc::new(m.clone()), "stored")
            }
            _ => {
                return error(
                    400,
                    "krr needs 'labels' or 'labels_file' (or an artifact \
                     saved with a fitted krr model)",
                )
            }
        }
    } else {
        let cfg = match resolve_task_config(&treq) {
            Ok(c) => c,
            Err(e) => return error(400, e),
        };
        let key = task_cache_key(&cfg, h.artifact.k());
        match fit_with_cache(&h.task_cache, &h.artifact.approx, &cfg, key) {
            Ok((m, cached)) => {
                ServerMetrics::inc(if cached {
                    &state.metrics.task_cache_hits
                } else {
                    &state.metrics.tasks_fitted
                });
                (m, if cached { "cached" } else { "fitted" })
            }
            Err(e) => return error(400, e),
        }
    };
    let predictions = if treq.predict.is_empty() {
        None
    } else {
        let kernel = h.artifact.kernel.build();
        let label = format!("artifact:{}", h.name);
        match run_predict(
            state,
            &label,
            &model,
            &*kernel,
            &h.artifact.selected_points,
            &treq,
        ) {
            Ok(p) => Some(p),
            Err(e) => return error(400, e),
        }
    };
    task_response(&h.name, &model, source, predictions.as_ref())
}

/// Persist a fresh snapshot of a live session as a stored artifact
/// (`POST /sessions/{name}/save`). The session keeps running.
fn save_session(state: &Arc<ServerState>, name: &str, req: &Request) -> Response {
    let h = match state.registry.get(name) {
        None => return error(404, format!("no session '{name}'")),
        Some(h) => h,
    };
    let sreq = match protocol::parse_save(&req.body_str()) {
        Ok(s) => s,
        Err(e) => return error(400, e),
    };
    let path = match protocol::resolve_fs_path(&state.config.fs_root, &sreq.path) {
        Ok(p) => p,
        Err(e) => return error(400, e),
    };
    let snap = match registry::ensure_snapshot(&h, true) {
        Ok(s) => s,
        Err(e) => return error(500, e),
    };
    let st = lock(&h.shared.stats).clone();
    // Λ's points via PointAccess: the whole dataset for ordinary
    // sessions, the leader-synced mirror for shard-read ones
    let selected = match h.points.selected_dataset(&snap.indices) {
        Ok(d) => d,
        Err(e) => return error(500, e),
    };
    let artifact = match crate::nystrom::StoredArtifact::from_selected(
        (*snap).clone(),
        selected,
        &*h.kernel,
        crate::nystrom::Provenance {
            source: h.source.to_string(),
            method: st.method,
        },
        st.error_estimate,
    ) {
        Ok(a) => a.with_f32(sreq.f32_payload),
        Err(e) => return error(400, e),
    };
    match artifact.save(&path) {
        Ok(bytes) => {
            ServerMetrics::inc(&state.metrics.artifacts_saved);
            Response::json(
                200,
                Json::obj(vec![
                    ("name", Json::Str(h.name.clone())),
                    ("path", Json::Str(sreq.path)),
                    ("n", Json::Num(artifact.n() as f64)),
                    ("k", Json::Num(artifact.k() as f64)),
                    ("bytes", Json::Num(bytes as f64)),
                ]),
            )
        }
        Err(e) => error(500, e),
    }
}

/// Host a stored artifact as a query-only read replica
/// (`POST /artifacts/load`).
fn load_artifact(state: &Arc<ServerState>, req: &Request) -> Response {
    let lreq = match protocol::parse_artifact_load(&req.body_str()) {
        Ok(l) => l,
        Err(e) => return error(400, e),
    };
    let path = match protocol::resolve_fs_path(&state.config.fs_root, &lreq.path) {
        Ok(p) => p,
        Err(e) => return error(400, e),
    };
    // pre-check for a clean 409; a lost race still errors safely below
    let duplicate = lreq
        .name
        .as_deref()
        .map(|n| state.artifacts.contains(n))
        .unwrap_or(false);
    // cap check from the header alone, *before* the payload is
    // materialized — mirroring how datasets are bounded during parse
    let (pn, pk, _pdim) = match crate::nystrom::StoredArtifact::peek_dims(&path)
    {
        Ok(d) => d,
        Err(e) => return error(400, e),
    };
    let elems = (pn as u128) * (pk as u128);
    if elems > protocol::MAX_STATE_ELEMS {
        return error(
            400,
            format!(
                "artifact n×k = {elems} exceeds the serving cap of {} state \
                 elements",
                protocol::MAX_STATE_ELEMS
            ),
        );
    }
    let artifact = match crate::nystrom::StoredArtifact::load(&path) {
        Ok(a) => a,
        Err(e) => return error(400, e),
    };
    // re-check against what actually loaded (the file could have been
    // swapped between the peek and the read)
    let elems = (artifact.n() as u128) * (artifact.k() as u128);
    if elems > protocol::MAX_STATE_ELEMS {
        return error(
            400,
            format!(
                "artifact n×k = {elems} exceeds the serving cap of {} state \
                 elements",
                protocol::MAX_STATE_ELEMS
            ),
        );
    }
    match state.artifacts.insert(lreq.name, artifact, lreq.path.into()) {
        Ok(hosted) => {
            ServerMetrics::inc(&state.metrics.artifacts_loaded);
            Response::json(200, hosted.status_json())
        }
        Err(e) => error(if duplicate { 409 } else { 400 }, e),
    }
}

fn list_artifacts(state: &Arc<ServerState>) -> Response {
    let artifacts: Vec<Json> = state
        .artifacts
        .list()
        .into_iter()
        .map(|h| h.status_json())
        .collect();
    Response::json(200, Json::obj(vec![("artifacts", Json::Arr(artifacts))]))
}

fn artifact_status(state: &Arc<ServerState>, name: &str) -> Response {
    match state.artifacts.get(name) {
        None => error(404, format!("no artifact '{name}'")),
        Some(h) => Response::json(200, h.status_json()),
    }
}

/// Out-of-sample extension against a loaded artifact — answered from the
/// stored factors and selected points only (`POST
/// /artifacts/{name}/query`). Response shape matches the session query.
fn query_artifact(state: &Arc<ServerState>, name: &str, req: &Request) -> Response {
    let h = match state.artifacts.get(name) {
        None => return error(404, format!("no artifact '{name}'")),
        Some(h) => h,
    };
    let q = match protocol::parse_query(&req.body_str()) {
        Ok(q) => q,
        Err(e) => return error(400, e),
    };
    let n = h.artifact.n();
    for &t in &q.targets {
        if t >= n {
            return error(400, format!("target index {t} out of range (n = {n})"));
        }
    }
    let mut results = Vec::with_capacity(q.points.len());
    for (i, p) in q.points.iter().enumerate() {
        let w = match h.artifact.query_weights(p) {
            Ok(w) => w,
            Err(e) => return error(400, format!("query point {i}: {e}")),
        };
        let mut fields = vec![("weights", protocol::num_arr(&w))];
        if !q.targets.is_empty() {
            match h.artifact.extend(&w, &q.targets) {
                Ok(vals) => fields.push(("kernel", protocol::num_arr(&vals))),
                Err(e) => return error(400, e),
            }
        }
        results.push(Json::obj(fields));
    }
    h.queries
        .fetch_add(q.points.len() as u64, std::sync::atomic::Ordering::Relaxed);
    ServerMetrics::inc(&state.metrics.artifact_queries);
    Response::json(
        200,
        Json::obj(vec![
            ("name", Json::Str(h.name.clone())),
            ("k", Json::Num(h.artifact.k() as f64)),
            ("results", Json::Arr(results)),
        ]),
    )
}

fn unload_artifact(state: &Arc<ServerState>, name: &str) -> Response {
    match state.artifacts.remove(name) {
        None => error(404, format!("no artifact '{name}'")),
        Some(h) => Response::json(
            200,
            Json::obj(vec![
                ("name", Json::Str(h.name.clone())),
                ("unloaded", Json::Bool(true)),
            ]),
        ),
    }
}

/// The batch-size histogram in its own units (points per call, not ms).
fn batch_hist_json(h: &crate::obs::Hist) -> Json {
    let q = |p: f64| if h.count() == 0 { 0.0 } else { h.quantile(p) };
    Json::obj(vec![
        ("count", Json::Num(h.count() as f64)),
        ("mean", Json::Num(h.mean())),
        ("last", Json::Num(h.last())),
        ("max", Json::Num(h.max())),
        ("p50", Json::Num(q(0.50))),
        ("p99", Json::Num(q(0.99))),
    ])
}

/// The `"predict"` section of the JSON `/metrics` report: the batch-size
/// histogram plus one latency histogram per served model.
fn predict_json(state: &Arc<ServerState>) -> Json {
    let per_model: Vec<(String, Json)> = state
        .metrics
        .predict_hists()
        .into_iter()
        .map(|(name, h)| (name, h.to_json()))
        .collect();
    Json::Obj(
        vec![
            (
                "batch_size".to_string(),
                batch_hist_json(&state.metrics.predict_batches()),
            ),
            ("models".to_string(), Json::Obj(per_model.into_iter().collect())),
        ]
        .into_iter()
        .collect(),
    )
}

fn metrics_report(state: &Arc<ServerState>) -> Response {
    let listed = state.registry.list();
    let sessions: Vec<Json> = listed
        .iter()
        .map(|(name, shared)| stats_json(name, &lock(&shared.stats).clone()))
        .collect();
    // convergence telemetry in summary form: full point lists stay on
    // the per-session /trajectory endpoint, the report carries only the
    // ring occupancy and the most recent point per session
    let trajectory: std::collections::BTreeMap<String, Json> = listed
        .iter()
        .map(|(name, shared)| {
            let t = lock(&shared.trajectory);
            (
                name.clone(),
                Json::obj(vec![
                    ("count", Json::Num(t.points.len() as f64)),
                    ("dropped", Json::Num(t.dropped as f64)),
                    (
                        "last",
                        t.points
                            .back()
                            .map(|p| p.to_json())
                            .unwrap_or(Json::Null),
                    ),
                ]),
            )
        })
        .collect();
    let artifacts: Vec<Json> = state
        .artifacts
        .list()
        .into_iter()
        .map(|h| h.status_json())
        .collect();
    Response::json(
        200,
        Json::obj(vec![
            (
                "uptime_secs",
                Json::Num(state.started.elapsed().as_secs_f64()),
            ),
            ("start_time_unix_secs", Json::Num(state.start_unix_secs)),
            ("version", Json::Str(env!("CARGO_PKG_VERSION").into())),
            ("server", state.metrics.to_json()),
            ("predict", predict_json(state)),
            ("sessions", Json::Arr(sessions)),
            ("trajectory", Json::Obj(trajectory)),
            ("artifacts", Json::Arr(artifacts)),
        ]),
    )
}

/// One distributed session's per-worker counters, flattened out of the
/// `"workers"` JSON array the coordinator mirrors into the session
/// stats — the Prometheus gauges are rendered from the same numbers the
/// JSON endpoint serves, so the two can never disagree mid-run.
struct WorkerRow {
    session: String,
    worker: String,
    columns_served: f64,
    argmax_rounds: f64,
    wire_bytes: f64,
    reshards: f64,
    heartbeat_age_secs: Option<f64>,
    dead: bool,
}

fn worker_rows(session: &str, workers: &Json) -> Vec<WorkerRow> {
    let num = |j: &Json, key: &str| {
        j.get(key).and_then(Json::as_f64).unwrap_or(0.0)
    };
    workers
        .as_arr()
        .map(|arr| {
            arr.iter()
                .map(|w| WorkerRow {
                    session: session.to_string(),
                    worker: format!("{}", num(w, "worker") as u64),
                    columns_served: num(w, "columns_served"),
                    argmax_rounds: num(w, "argmax_rounds"),
                    wire_bytes: num(w, "wire_bytes"),
                    reshards: num(w, "reshards_absorbed"),
                    heartbeat_age_secs: w
                        .get("last_heartbeat_age_ms")
                        .and_then(Json::as_f64)
                        .map(|ms| ms * 1e-3),
                    dead: w
                        .get("dead")
                        .and_then(Json::as_bool)
                        .unwrap_or(false),
                })
                .collect()
        })
        .unwrap_or_default()
}

/// The Prometheus text rendering of `/metrics`: build info and uptime,
/// every server counter, per-endpoint request-duration histograms,
/// per-session step histograms and progress gauges, and — for live
/// oasis-p sessions — per-worker gauges. Validated end to end by
/// `oasis promcheck` in the CI smoke jobs.
fn metrics_prometheus(state: &Arc<ServerState>) -> Response {
    use crate::obs::prom::{self, PromText};
    let mut page = PromText::new();
    page.family("oasis_build_info", "Build information.", "gauge");
    page.sample(
        "oasis_build_info",
        &[("version", env!("CARGO_PKG_VERSION"))],
        1.0,
    );
    page.gauge(
        "oasis_start_time_seconds",
        "Unix time the server started.",
        state.start_unix_secs,
    );
    page.gauge(
        "oasis_uptime_seconds",
        "Seconds since the server started.",
        state.started.elapsed().as_secs_f64(),
    );
    for (name, help, value) in state.metrics.counter_triples() {
        page.counter(name, help, value as f64);
    }
    let hists = state.metrics.endpoint_hists();
    if !hists.is_empty() {
        page.family(
            "oasis_http_request_duration_seconds",
            "Request latency by normalized endpoint.",
            "histogram",
        );
        for (endpoint, h) in &hists {
            page.histogram(
                "oasis_http_request_duration_seconds",
                &[("endpoint", endpoint)],
                h,
            );
        }
    }
    let predict = state.metrics.predict_hists();
    if !predict.is_empty() {
        page.family(
            "oasis_predict_duration_seconds",
            "Task-endpoint prediction latency by served model.",
            "histogram",
        );
        for (model, h) in &predict {
            page.histogram(
                "oasis_predict_duration_seconds",
                &[("model", model)],
                h,
            );
        }
        page.family(
            "oasis_predict_batch_size",
            "Points per task-endpoint predict call.",
            "histogram",
        );
        page.histogram(
            "oasis_predict_batch_size",
            &[],
            &state.metrics.predict_batches(),
        );
    }
    let stats: Vec<(String, SessionStats)> = state
        .registry
        .list()
        .into_iter()
        .map(|(name, shared)| {
            let st = lock(&shared.stats).clone();
            (name, st)
        })
        .collect();
    page.gauge(
        "oasis_sessions_live",
        "Sessions currently hosted.",
        stats.len() as f64,
    );
    page.gauge(
        "oasis_artifacts_hosted",
        "Artifacts currently hosted.",
        state.artifacts.list().len() as f64,
    );
    if !stats.is_empty() {
        page.family(
            "oasis_session_columns",
            "Columns selected so far (k), including seed columns.",
            "gauge",
        );
        for (name, st) in &stats {
            page.sample(
                "oasis_session_columns",
                &[("session", name)],
                st.k as f64,
            );
        }
        page.family(
            "oasis_session_steps_total",
            "Adaptive selections performed over the session's lifetime.",
            "counter",
        );
        for (name, st) in &stats {
            page.sample(
                "oasis_session_steps_total",
                &[("session", name)],
                st.steps_done as f64,
            );
        }
        page.family(
            "oasis_session_error_estimate",
            "Most recent error estimate (max Δ), when available.",
            "gauge",
        );
        for (name, st) in &stats {
            if let Some(e) = st.error_estimate {
                page.sample(
                    "oasis_session_error_estimate",
                    &[("session", name)],
                    e,
                );
            }
        }
        page.family(
            "oasis_session_best_score",
            "Δ-score of the most recent adaptive selection, when scored.",
            "gauge",
        );
        for (name, st) in &stats {
            if let Some(s) = st.best_score.filter(|s| s.is_finite()) {
                page.sample(
                    "oasis_session_best_score",
                    &[("session", name)],
                    s,
                );
            }
        }
        if stats.iter().any(|(_, st)| st.step_latency.count() > 0) {
            page.family(
                "oasis_session_step_duration_seconds",
                "Per-step selection latency.",
                "histogram",
            );
            for (name, st) in &stats {
                if st.step_latency.count() > 0 {
                    page.histogram(
                        "oasis_session_step_duration_seconds",
                        &[("session", name)],
                        &st.step_latency,
                    );
                }
            }
        }
    }
    let rows: Vec<WorkerRow> = stats
        .iter()
        .filter_map(|(name, st)| st.workers.as_ref().map(|w| worker_rows(name, w)))
        .flatten()
        .collect();
    if !rows.is_empty() {
        let worker_counters: [(&str, &str, fn(&WorkerRow) -> f64); 4] = [
            (
                "oasis_worker_columns_served_total",
                "Kernel columns served by this worker.",
                |r| r.columns_served,
            ),
            (
                "oasis_worker_argmax_rounds_total",
                "Argmax gather rounds this worker answered.",
                |r| r.argmax_rounds,
            ),
            (
                "oasis_worker_wire_bytes_total",
                "Bytes this worker put on the wire (TCP fleets).",
                |r| r.wire_bytes,
            ),
            (
                "oasis_worker_reshards_total",
                "Row ranges this worker absorbed from dead peers.",
                |r| r.reshards,
            ),
        ];
        for (name, help, get) in worker_counters {
            page.family(name, help, "counter");
            for r in &rows {
                page.sample(
                    name,
                    &[("session", &r.session), ("worker", &r.worker)],
                    get(r),
                );
            }
        }
        page.family(
            "oasis_worker_heartbeat_age_seconds",
            "Seconds since this worker's last message (TCP fleets).",
            "gauge",
        );
        for r in &rows {
            if let Some(age) = r.heartbeat_age_secs {
                page.sample(
                    "oasis_worker_heartbeat_age_seconds",
                    &[("session", &r.session), ("worker", &r.worker)],
                    age,
                );
            }
        }
        page.family(
            "oasis_worker_dead",
            "1 when the leader declared this worker dead.",
            "gauge",
        );
        for r in &rows {
            page.sample(
                "oasis_worker_dead",
                &[("session", &r.session), ("worker", &r.worker)],
                if r.dead { 1.0 } else { 0.0 },
            );
        }
    }
    Response::text(200, prom::CONTENT_TYPE, page.finish())
}
