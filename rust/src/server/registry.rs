//! The session registry: named, concurrent, resumable sampler sessions.
//!
//! ## Why an actor thread per session
//!
//! The sequential sampler sessions borrow their
//! [`ColumnOracle`](crate::sampling::ColumnOracle) (and through it the
//! dataset and kernel), so a live session cannot hop between
//! request-handler threads. Each hosted session therefore runs on a
//! dedicated **actor thread** that keeps the dataset and kernel alive via
//! `Arc`, constructs the oracle and session on its own stack, and
//! serializes commands received over a channel: stepping, snapshots and
//! finish all execute on that thread, while request handlers only ever
//! exchange owned `Send` values ([`StepReport`], `Arc<NystromApprox>`).
//! This also gives per-session mutual exclusion for free — two clients
//! stepping the same session are simply queued in arrival order — while
//! distinct sessions run fully in parallel.
//!
//! Cheap read paths never touch the actor: every actor mirrors its
//! externally visible state into a shared [`SessionShared`] (stats +
//! cached snapshot) that `/metrics`, `GET /sessions/{name}` and queries
//! read lock-only.

use super::protocol::{CreateRequest, Method};
use crate::data::Dataset;
use crate::engine::{ResolvedRun, RunData, SessionBuilder};
use crate::kernels::Kernel;
use crate::nystrom::NystromApprox;
use crate::obs::Hist;
use crate::sampling::{SamplerSession, StepOutcome, StopReason, StoppingRule};
use crate::util::json::Json;
use crate::Result;
use crate::{anyhow, bail};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

/// Non-poisoning lock helper: a panicked writer must not take the whole
/// server down with it.
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Most recent trajectory points a session keeps (per selection step);
/// older points are dropped oldest-first and counted in
/// [`Trajectory::dropped`].
pub const TRAJECTORY_CAP: usize = 2048;

/// One sampled point of a session's convergence trajectory — recorded
/// by the actor thread after every successful selection step, off the
/// same snapshot path the stats mirror uses.
#[derive(Clone, Debug)]
pub struct TrajectoryPoint {
    /// Lifetime step number (1-based; equals `steps_done` at record
    /// time).
    pub step: u64,
    /// Columns selected after this step (including seed columns).
    pub k: usize,
    /// The session's error estimate after this step, if the method has
    /// an estimator.
    pub error_estimate: Option<f64>,
    /// The selection score |Δ| of the column this step picked (NaN for
    /// randomized draws without a score).
    pub best_score: f64,
    /// Wall-clock microseconds the step took on the actor.
    pub step_us: u64,
}

impl TrajectoryPoint {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("step", Json::Num(self.step as f64)),
            ("k", Json::Num(self.k as f64)),
            (
                "error_estimate",
                super::protocol::opt_num(self.error_estimate),
            ),
            (
                "best_score",
                if self.best_score.is_finite() {
                    Json::Num(self.best_score)
                } else {
                    Json::Null
                },
            ),
            ("step_us", Json::Num(self.step_us as f64)),
        ])
    }
}

/// Bounded per-session trajectory ring (see
/// [`SessionShared::trajectory`]).
#[derive(Debug, Default)]
pub struct Trajectory {
    pub points: std::collections::VecDeque<TrajectoryPoint>,
    /// Points the ring discarded oldest-first once past
    /// [`TRAJECTORY_CAP`].
    pub dropped: u64,
}

impl Trajectory {
    pub fn push(&mut self, p: TrajectoryPoint) {
        if self.points.len() == TRAJECTORY_CAP {
            self.points.pop_front();
            self.dropped += 1;
        }
        self.points.push_back(p);
    }
}

/// Externally visible state of one hosted session, mirrored by its actor
/// thread after every step batch (and per step for latencies).
#[derive(Clone, Debug, Default)]
pub struct SessionStats {
    /// Method name as reported by the session (e.g. "oASIS").
    pub method: String,
    pub n: usize,
    /// Columns selected so far (including seed columns).
    pub k: usize,
    pub error_estimate: Option<f64>,
    /// Most recent external/internal stop, if any (a stopped session can
    /// still be stepped further — rules are per-request).
    pub stop: Option<StopReason>,
    /// An actor is currently inside a step batch.
    pub busy: bool,
    /// Finish was processed; the session is gone.
    pub finished: bool,
    /// Adaptive selections performed over the session's lifetime.
    pub steps_done: u64,
    /// The session's own selection-work clock (see
    /// [`SamplerSession::selection_secs`]).
    pub selection_secs: f64,
    /// Per-step selection latencies (log₂ buckets; `/metrics` renders
    /// the p50/p90/p99 estimates alongside mean/last/max).
    pub step_latency: Hist,
    /// Selection score |Δ| of the most recent step (the
    /// `oasis_session_best_score` Prometheus gauge; `None` before the
    /// first adaptive step or for unscored randomized draws).
    pub best_score: Option<f64>,
    /// Message of the first step error, if one occurred.
    pub failed: Option<String>,
    /// Per-worker coordinator counters (distributed sessions only; see
    /// [`SamplerSession::worker_stats`]).
    pub workers: Option<Json>,
}

/// Stats plus the cached snapshot, shared between the actor thread and
/// request handlers.
#[derive(Debug, Default)]
pub struct SessionShared {
    pub stats: Mutex<SessionStats>,
    /// Most recent snapshot; reused across queries until refreshed.
    pub snapshot: Mutex<Option<Arc<NystromApprox>>>,
    /// Set at server shutdown: step batches poll this between steps so a
    /// queued million-step background batch cannot stall
    /// [`Registry::shutdown`]'s join.
    pub cancel: AtomicBool,
    /// Shard-read sessions mirror `(global index, point)` for every
    /// selected column here (synced from the session by its actor after
    /// construction and every step batch — see
    /// [`SamplerSession::selected_points`]); the server holds no dataset
    /// for them, and queries/saves only ever touch Λ's points.
    pub selected_mirror: Mutex<Vec<(usize, Vec<f64>)>>,
    /// Gates the mirror sync so full-dataset sessions do not pay the
    /// per-batch O(k·dim) copy they would never read.
    pub mirror_points: AtomicBool,
    /// Most recent fitted downstream-task model, keyed by its full
    /// config + the k it was fit at — repeated identical task requests
    /// (the common serve pattern: fit once, predict many) skip the
    /// O(nk²) refit. Replaced whenever the key changes.
    pub task_cache: Mutex<Option<CachedTask>>,
    /// Convergence-telemetry ring: one [`TrajectoryPoint`] per
    /// selection step, bounded at [`TRAJECTORY_CAP`] (oldest dropped).
    /// Served by `GET /sessions/{name}/trajectory` and summarized in
    /// the `"trajectory"` section of JSON `/metrics`.
    pub trajectory: Mutex<Trajectory>,
}

/// One cached fitted task model (see
/// [`SessionShared::task_cache`] and the artifact registry's
/// equivalent).
#[derive(Debug)]
pub struct CachedTask {
    /// Canonical rendering of the task config + labels checksum + k.
    pub key: String,
    /// The exact label columns (output-major) the model was fit with —
    /// compared on every hit, because the key only carries a 64-bit
    /// hash of them and FNV is not collision-resistant.
    pub labels: Option<Vec<Vec<f64>>>,
    pub model: Arc<crate::tasks::FittedTask>,
}

/// What one step batch did.
#[derive(Clone, Debug)]
pub struct StepReport {
    pub k: usize,
    /// Selections actually performed in this batch (≤ requested steps).
    pub stepped: usize,
    /// Why the batch ended early, if it did.
    pub stop: Option<StopReason>,
    pub error_estimate: Option<f64>,
    /// Wall-clock seconds the batch took on the actor.
    pub secs: f64,
}

/// Commands processed by a session's actor thread, in arrival order.
pub enum Command {
    /// Advance by up to `steps` selections, checking `rule` before every
    /// step. `reply: None` runs the batch in the background (the caller
    /// already got 202; progress is visible through [`SessionShared`]).
    Step {
        steps: usize,
        rule: StoppingRule,
        reply: Option<Sender<Result<StepReport>>>,
    },
    /// Assemble the current factors without ending the run; also refreshes
    /// the shared snapshot cache.
    Snapshot { reply: Sender<Result<Arc<NystromApprox>>> },
    /// Consume the session and return the final approximation.
    Finish { reply: Sender<Result<NystromApprox>> },
}

/// How request handlers resolve data points for a hosted session.
///
/// Every method except shard-read oASIS-P keeps the whole dataset alive
/// in the server (`Full`) — queries evaluate `k(z, xⱼ)` against
/// arbitrary selected rows, and saves extract Λ's points. A shard-read
/// session holds no dataset — its workers own the shards — so the
/// handlers fall back to the selected-points mirror its actor syncs from
/// the leader ([`SessionShared::selected_mirror`]): Λ's points are all
/// the query, save, and status paths ever touch.
#[derive(Clone)]
pub enum PointAccess {
    Full(Arc<Dataset>),
    Selected { n: usize, dim: usize, shared: Arc<SessionShared> },
}

impl PointAccess {
    pub fn n(&self) -> usize {
        match self {
            PointAccess::Full(ds) => ds.n(),
            PointAccess::Selected { n, .. } => *n,
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            PointAccess::Full(ds) => ds.dim(),
            PointAccess::Selected { dim, .. } => *dim,
        }
    }

    /// The Nyström extension's `b(z) = [k(z, x_j)]` over the given
    /// selected indices.
    pub fn kernel_row(
        &self,
        kernel: &dyn Kernel,
        z: &[f64],
        indices: &[usize],
    ) -> Result<Vec<f64>> {
        match self {
            PointAccess::Full(ds) => Ok(indices
                .iter()
                .map(|&j| kernel.eval(z, ds.point(j)))
                .collect()),
            PointAccess::Selected { shared, .. } => {
                let mirror = lock(&shared.selected_mirror);
                indices
                    .iter()
                    .enumerate()
                    .map(|(t, &j)| {
                        lookup_mirrored(&mirror, t, j).map(|p| kernel.eval(z, p))
                    })
                    .collect()
            }
        }
    }

    /// The points of `indices`, as a dataset (what artifact saves embed).
    pub fn selected_dataset(&self, indices: &[usize]) -> Result<Dataset> {
        match self {
            PointAccess::Full(ds) => {
                if let Some(&bad) = indices.iter().find(|&&i| i >= ds.n()) {
                    bail!("selected index {bad} out of range (n = {})", ds.n());
                }
                Ok(ds.select(indices))
            }
            PointAccess::Selected { shared, dim, .. } => {
                if indices.is_empty() {
                    // let the caller's own empty-Λ validation speak
                    return Ok(Dataset::zeros(0, *dim));
                }
                let mirror = lock(&shared.selected_mirror);
                let mut rows = Vec::with_capacity(indices.len());
                for (t, &j) in indices.iter().enumerate() {
                    rows.push(lookup_mirrored(&mirror, t, j)?.to_vec());
                }
                Ok(Dataset::from_rows(rows))
            }
        }
    }
}

/// Mirror lookup for global index `j`, trying position `t` first (a
/// snapshot's indices and the mirror share selection order, so the fast
/// path almost always hits).
fn lookup_mirrored<'m>(
    mirror: &'m [(usize, Vec<f64>)],
    t: usize,
    j: usize,
) -> Result<&'m [f64]> {
    match mirror.get(t) {
        Some((g, p)) if *g == j => Ok(p),
        _ => mirror
            .iter()
            .find(|(g, _)| *g == j)
            .map(|(_, p)| p.as_slice())
            .ok_or_else(|| {
                anyhow!(
                    "selected point {j} is not mirrored yet — retry after the \
                     current step batch"
                )
            }),
    }
}

/// Handler-side handle to one hosted session. Cloneable; all fields are
/// shared-ownership or channel endpoints.
#[derive(Clone)]
pub struct SessionHandle {
    pub name: String,
    pub tx: Sender<Command>,
    pub shared: Arc<SessionShared>,
    /// Point resolution for queries/saves (whole dataset, or the
    /// shard-read selected-points mirror).
    pub points: PointAccess,
    pub kernel: Arc<dyn Kernel + Send + Sync>,
    /// Dataset provenance line (recorded into saved artifacts).
    pub source: Arc<str>,
}

struct Entry {
    handle: SessionHandle,
    join: std::thread::JoinHandle<()>,
}

/// Named live sessions.
pub struct Registry {
    inner: Mutex<HashMap<String, Entry>>,
    counter: AtomicU64,
    /// Set by [`shutdown`](Registry::shutdown); a create that loses the
    /// race against shutdown must not insert a session nobody will join.
    closed: AtomicBool,
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry {
            inner: Mutex::new(HashMap::new()),
            counter: AtomicU64::new(0),
            closed: AtomicBool::new(false),
        }
    }

    /// Create a session: resolve the request's [`RunSpec`] through the
    /// engine (dataset/kernel/warm-start, under the serving caps), spawn
    /// the actor thread, and wait for it to report that session
    /// construction succeeded — so construction errors (singular seeds,
    /// bad configs, mismatched warm-start artifacts) surface
    /// synchronously as a clean request error.
    ///
    /// [`RunSpec`]: crate::engine::RunSpec
    pub fn create(&self, req: CreateRequest) -> Result<SessionHandle> {
        let name = match req.name {
            Some(n) => {
                if lock(&self.inner).contains_key(&n) {
                    bail!("session '{n}' already exists");
                }
                n
            }
            // auto names skip anything taken (a user may have claimed
            // "s0" explicitly); a residual race is caught at insert
            None => loop {
                let candidate =
                    format!("s{}", self.counter.fetch_add(1, Ordering::Relaxed));
                if !lock(&self.inner).contains_key(&candidate) {
                    break candidate;
                }
            },
        };
        let run = SessionBuilder::with_limits(super::protocol::serving_load_limits())
            .resolve(req.spec)?;
        // serving-sanity caps: one request must not be able to abort the
        // whole server with an oversized allocation (see protocol's caps;
        // the engine already clamped max_cols/init_cols to n). Warm-start
        // resolution is header-only (peek_warm_start never materializes
        // the artifact's n×k payload), so capping the *resolved* warm k
        // here — one read, no check-to-use window — bounds the session
        // state a replay would grow to.
        let n = run.n();
        let spec = &run.method;
        if matches!(spec.method, Method::Farahat | Method::AdaptiveRandom)
            && n > super::protocol::MAX_RESIDUAL_N
        {
            bail!(
                "method '{:?}' materializes an n×n residual; n = {n} exceeds \
                 the serving cap of {}",
                spec.method,
                super::protocol::MAX_RESIDUAL_N
            );
        }
        let state_cols = spec
            .max_cols
            .max(run.warm.as_ref().map_or(0, |w| w.indices.len()));
        if (n as u128) * (state_cols as u128) > super::protocol::MAX_STATE_ELEMS {
            bail!(
                "n × columns = {} exceeds the serving cap of {} state \
                 elements — lower max_cols (or warm-start from a smaller \
                 artifact)",
                (n as u128) * (state_cols as u128),
                super::protocol::MAX_STATE_ELEMS
            );
        }
        // oasis-p replicates a max_cols×max_cols W⁻¹ on every worker
        if spec.method == Method::OasisP {
            let replicas = (spec.workers as u128)
                * (spec.max_cols as u128)
                * (spec.max_cols as u128);
            if replicas > super::protocol::MAX_STATE_ELEMS {
                bail!(
                    "workers × max_cols² = {replicas} exceeds the serving cap \
                     of {} state elements — lower workers or max_cols",
                    super::protocol::MAX_STATE_ELEMS
                );
            }
        }

        let shared = Arc::new(SessionShared::default());
        let points = match &run.data {
            RunData::Full(ds) => PointAccess::Full(ds.clone()),
            RunData::ShardFile { n, dim, .. } => {
                shared.mirror_points.store(true, Ordering::SeqCst);
                PointAccess::Selected { n: *n, dim: *dim, shared: shared.clone() }
            }
        };
        let (tx, rx) = mpsc::channel();
        let (ready_tx, ready_rx) = mpsc::channel();
        let handle = SessionHandle {
            name: name.clone(),
            tx,
            shared: shared.clone(),
            points,
            kernel: run.kernel.clone(),
            source: run.source.clone().into(),
        };
        let join = std::thread::Builder::new()
            .name(format!("oasis-session-{name}"))
            .spawn(move || session_thread(run, shared, rx, ready_tx))
            .map_err(|e| anyhow!("could not spawn session thread: {e}"))?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                let _ = join.join();
                return Err(e.wrap(format!("creating session '{name}'")));
            }
            Err(_) => {
                let _ = join.join();
                bail!("session '{name}': construction thread died");
            }
        }
        {
            let mut map = lock(&self.inner);
            // both rejection cases tear the fresh actor down again
            // (dropping its only Sender ends its loop). The `closed` check
            // under the map lock makes create/shutdown serializable: either
            // this insert lands before shutdown's drain (which then removes
            // and joins it), or it observes `closed` and backs out — no
            // session can outlive `Registry::shutdown`.
            let refused = if self.closed.load(Ordering::SeqCst) {
                Some("server is shutting down".to_string())
            } else if map.contains_key(&name) {
                Some(format!("session '{name}' already exists"))
            } else {
                None
            };
            if let Some(msg) = refused {
                drop(map);
                drop(handle);
                let _ = join.join();
                return Err(anyhow!("{msg}"));
            }
            map.insert(name.clone(), Entry { handle: handle.clone(), join });
        }
        Ok(handle)
    }

    pub fn get(&self, name: &str) -> Option<SessionHandle> {
        lock(&self.inner).get(name).map(|e| e.handle.clone())
    }

    /// Remove a session for finish/evict: exactly one caller wins the
    /// entry (and with it the join handle).
    pub fn remove(
        &self,
        name: &str,
    ) -> Option<(SessionHandle, std::thread::JoinHandle<()>)> {
        lock(&self.inner).remove(name).map(|e| (e.handle, e.join))
    }

    /// Name + shared state of every live session, name-sorted.
    pub fn list(&self) -> Vec<(String, Arc<SessionShared>)> {
        let mut out: Vec<_> = lock(&self.inner)
            .iter()
            .map(|(k, e)| (k.clone(), e.handle.shared.clone()))
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    pub fn len(&self) -> usize {
        lock(&self.inner).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every session (server shutdown): closing each command channel
    /// ends its actor loop; joining bounds the shutdown. Distributed
    /// sessions tear their worker threads down in their `Drop`. Also
    /// closes the registry: creations racing this call are refused (see
    /// [`create`](Registry::create)).
    pub fn shutdown(&self) {
        self.closed.store(true, Ordering::SeqCst);
        let entries: Vec<Entry> = {
            let mut map = lock(&self.inner);
            map.drain().map(|(_, e)| e).collect()
        };
        // interrupt running/queued step batches first so the joins below
        // are bounded by one selection step, not one batch
        for e in &entries {
            e.handle.shared.cancel.store(true, Ordering::SeqCst);
        }
        for e in entries {
            drop(e.handle);
            let _ = e.join.join();
        }
    }
}

/// Send a synchronous step batch to the session's actor.
pub fn step_sync(
    handle: &SessionHandle,
    steps: usize,
    rule: StoppingRule,
) -> Result<StepReport> {
    let (tx, rx) = mpsc::channel();
    handle
        .tx
        .send(Command::Step { steps, rule, reply: Some(tx) })
        .map_err(|_| anyhow!("session '{}' is already finished", handle.name))?;
    rx.recv()
        .map_err(|_| anyhow!("session '{}' terminated", handle.name))?
}

/// Enqueue a background step batch (fire and forget).
pub fn step_background(
    handle: &SessionHandle,
    steps: usize,
    rule: StoppingRule,
) -> Result<()> {
    handle
        .tx
        .send(Command::Step { steps, rule, reply: None })
        .map_err(|_| anyhow!("session '{}' is already finished", handle.name))
}

/// The session's current snapshot: the cached one if present (and
/// `refresh` is false), otherwise a fresh one taken by the actor.
pub fn ensure_snapshot(
    handle: &SessionHandle,
    refresh: bool,
) -> Result<Arc<NystromApprox>> {
    if !refresh {
        if let Some(s) = lock(&handle.shared.snapshot).clone() {
            return Ok(s);
        }
    }
    let (tx, rx) = mpsc::channel();
    handle
        .tx
        .send(Command::Snapshot { reply: tx })
        .map_err(|_| anyhow!("session '{}' is already finished", handle.name))?;
    rx.recv()
        .map_err(|_| anyhow!("session '{}' terminated", handle.name))?
}

/// Finish the session: the final approximation, after which the actor
/// thread exits. The caller should have removed the registry entry first
/// (so no new commands can be enqueued) and joins the thread afterwards.
/// Step batches still queued ahead of the Finish are interrupted via the
/// cancel flag — an evicted session's million-step background batch must
/// not make its finisher wait for hours.
pub fn finish(handle: &SessionHandle) -> Result<NystromApprox> {
    handle.shared.cancel.store(true, Ordering::SeqCst);
    let (tx, rx) = mpsc::channel();
    handle
        .tx
        .send(Command::Finish { reply: tx })
        .map_err(|_| anyhow!("session '{}' is already finished", handle.name))?;
    rx.recv()
        .map_err(|_| anyhow!("session '{}' terminated", handle.name))?
}

/// Actor-thread body: pin the resolved run's oracle on this stack (the
/// sequential sessions borrow it), open the session through the engine,
/// report construction, serve commands.
fn session_thread(
    run: ResolvedRun,
    shared: Arc<SessionShared>,
    rx: Receiver<Command>,
    ready: Sender<Result<()>>,
) {
    let slot = run.oracle_slot();
    match run.open_session(&slot) {
        Ok(session) => {
            sync_stats(&shared, session.as_ref(), None);
            let _ = ready.send(Ok(()));
            drive(session, &shared, &rx);
        }
        Err(e) => {
            let _ = ready.send(Err(e));
        }
    }
}

/// The actor loop: commands strictly in arrival order, one at a time.
fn drive(
    mut session: Box<dyn SamplerSession + '_>,
    shared: &SessionShared,
    rx: &Receiver<Command>,
) {
    loop {
        let cmd = match rx.recv() {
            Ok(c) => c,
            // every Sender dropped (session evicted / server shutdown)
            Err(_) => return,
        };
        match cmd {
            Command::Step { steps, rule, reply } => {
                lock(&shared.stats).busy = true;
                let report = step_batch(session.as_mut(), steps, &rule, shared);
                {
                    let mut st = lock(&shared.stats);
                    st.busy = false;
                    // keep the *first* failure: later errors are usually
                    // downstream of the original root cause
                    if st.failed.is_none() {
                        if let Err(e) = &report {
                            st.failed = Some(e.to_string());
                        }
                    }
                }
                if let Some(tx) = reply {
                    let _ = tx.send(report);
                }
            }
            Command::Snapshot { reply } => {
                let res = session.snapshot().map(Arc::new);
                if let Ok(snap) = &res {
                    *lock(&shared.snapshot) = Some(snap.clone());
                }
                let _ = reply.send(res);
            }
            Command::Finish { reply } => {
                let res = session.finish();
                {
                    let mut st = lock(&shared.stats);
                    st.finished = true;
                    st.busy = false;
                }
                let _ = reply.send(res);
                return;
            }
        }
    }
}

/// Drive up to `steps` selections under `rule`, mirroring
/// [`run_to_completion`](crate::sampling::run_to_completion)'s
/// evaluate-before-step semantics, while recording per-step latency into
/// the shared stats.
fn step_batch(
    session: &mut dyn SamplerSession,
    steps: usize,
    rule: &StoppingRule,
    shared: &SessionShared,
) -> Result<StepReport> {
    let started = Instant::now();
    let mut stepped = 0usize;
    let mut stop: Option<StopReason> = None;
    while stepped < steps {
        if shared.cancel.load(Ordering::SeqCst) {
            break; // server shutting down; report what was done
        }
        if let Some(r) = rule.evaluate(session, started.elapsed()) {
            stop = Some(r);
            break;
        }
        let t0 = Instant::now();
        match session.step()? {
            StepOutcome::Selected { score, .. } => {
                stepped += 1;
                let secs = t0.elapsed().as_secs_f64();
                let err = session.error_estimate();
                let step_no;
                {
                    let mut st = lock(&shared.stats);
                    st.k = session.k();
                    st.steps_done += 1;
                    st.step_latency.record(secs);
                    if score.is_finite() {
                        st.best_score = Some(score);
                    }
                    step_no = st.steps_done;
                }
                lock(&shared.trajectory).push(TrajectoryPoint {
                    step: step_no,
                    k: session.k(),
                    error_estimate: err,
                    best_score: score,
                    step_us: (secs * 1e6) as u64,
                });
            }
            StepOutcome::Exhausted(r) => {
                stop = Some(r);
                break;
            }
        }
    }
    sync_stats(shared, session, stop);
    Ok(StepReport {
        k: session.k(),
        stepped,
        stop,
        error_estimate: session.error_estimate(),
        secs: started.elapsed().as_secs_f64(),
    })
}

fn sync_stats(
    shared: &SessionShared,
    session: &dyn SamplerSession,
    stop: Option<StopReason>,
) {
    {
        let mut st = lock(&shared.stats);
        if st.method.is_empty() {
            st.method = session.name().to_string();
        }
        st.n = session.n();
        st.k = session.k();
        st.error_estimate = session.error_estimate();
        st.selection_secs = session.selection_secs();
        st.workers = session.worker_stats();
        if stop.is_some() {
            st.stop = stop;
        }
    }
    // shard-read sessions: extend the selected-points mirror the
    // handlers' queries and saves read. Selection is append-only, so
    // only the tail past what is already mirrored is fetched — O(new
    // columns), not O(k), per batch. (Commands on one actor serialize,
    // so by the time a snapshot/query command runs, the mirror covers
    // every batch that preceded it.)
    if shared.mirror_points.load(Ordering::Relaxed) {
        let order = session.indices();
        let mut mirror = lock(&shared.selected_mirror);
        let have = mirror.len();
        if order.len() > have {
            if let Some(pts) = session.selected_points(have) {
                mirror.extend(order[have..].iter().copied().zip(pts));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::protocol::{
        DatasetSpec, KernelSpec, MethodSpec, RunSpec,
    };

    fn create_req(name: &str, n: usize, max_cols: usize, seed: u64) -> CreateRequest {
        CreateRequest {
            name: Some(name.to_string()),
            spec: RunSpec {
                dataset: DatasetSpec::Generator {
                    name: "two-moons".into(),
                    n,
                    seed: 42,
                    noise: 0.05,
                    dim: 0,
                },
                kernel: KernelSpec::Gaussian {
                    sigma: None,
                    sigma_fraction: 0.05,
                },
                method: MethodSpec {
                    method: Method::Oasis,
                    max_cols,
                    init_cols: 5,
                    tol: 1e-12,
                    seed,
                    batch: 10,
                    workers: 2,
                    merge_batch: 1,
                    listen: None,
                },
                stopping: StoppingRule::new(),
                shard_reads: false,
                warm_start: None,
            },
        }
    }

    #[test]
    fn create_step_snapshot_finish_lifecycle() {
        let reg = Registry::new();
        let h = reg.create(create_req("a", 200, 40, 7)).unwrap();
        assert_eq!(reg.len(), 1);
        assert_eq!(lock(&h.shared.stats).k, 5, "seed columns visible at create");

        let rep = step_sync(&h, 10, StoppingRule::new()).unwrap();
        assert_eq!(rep.stepped, 10);
        assert_eq!(rep.k, 15);
        assert!(rep.stop.is_none());
        assert_eq!(lock(&h.shared.stats).steps_done, 10);

        let snap = ensure_snapshot(&h, true).unwrap();
        assert_eq!(snap.k(), 15);
        // cached reuse returns the same Arc
        let again = ensure_snapshot(&h, false).unwrap();
        assert!(Arc::ptr_eq(&snap, &again));

        let (h2, join) = reg.remove("a").unwrap();
        let fin = finish(&h2).unwrap();
        let _ = join.join();
        assert_eq!(fin.k(), 15);
        assert!(lock(&h2.shared.stats).finished);
        assert!(reg.is_empty());
        // further commands fail cleanly
        assert!(step_sync(&h, 1, StoppingRule::new()).is_err());
    }

    #[test]
    fn step_batch_respects_rule() {
        let reg = Registry::new();
        let h = reg.create(create_req("r", 150, 60, 3)).unwrap();
        // budget below current k stops immediately with zero steps
        let rep = step_sync(&h, 10, StoppingRule::budget(3)).unwrap();
        assert_eq!(rep.stepped, 0);
        assert_eq!(rep.stop, Some(StopReason::BudgetReached));
        // generous budget: the steps cap binds instead
        let rep = step_sync(&h, 4, StoppingRule::budget(100)).unwrap();
        assert_eq!(rep.stepped, 4);
        assert!(rep.stop.is_none());
        reg.shutdown();
    }

    #[test]
    fn duplicate_names_rejected() {
        let reg = Registry::new();
        let _a = reg.create(create_req("dup", 80, 20, 1)).unwrap();
        let err = reg.create(create_req("dup", 80, 20, 1)).unwrap_err();
        assert!(format!("{err}").contains("already exists"));
        assert_eq!(reg.len(), 1);
        reg.shutdown();
        assert!(reg.is_empty());
    }

    #[test]
    fn background_steps_progress_via_shared_stats() {
        let reg = Registry::new();
        let h = reg.create(create_req("bg", 200, 50, 5)).unwrap();
        step_background(&h, 20, StoppingRule::new()).unwrap();
        // a sync no-op step queues behind the background batch, so once it
        // returns the background work is done
        let rep = step_sync(&h, 1, StoppingRule::budget(1)).unwrap();
        assert_eq!(rep.stepped, 0);
        assert_eq!(lock(&h.shared.stats).k, 25);
        assert_eq!(lock(&h.shared.stats).steps_done, 20);
        reg.shutdown();
    }

    #[test]
    fn hosts_every_method() {
        let reg = Registry::new();
        for (i, m) in [
            Method::Oasis,
            Method::Sis,
            Method::Farahat,
            Method::Icd,
            Method::AdaptiveRandom,
            Method::OasisP,
        ]
        .into_iter()
        .enumerate()
        {
            let mut req = create_req(&format!("m{i}"), 60, 12, 2);
            req.spec.method.method = m;
            let h = reg.create(req).unwrap();
            let rep = step_sync(&h, 3, StoppingRule::new()).unwrap();
            assert!(rep.stepped >= 1, "{m:?} did not step");
            let snap = ensure_snapshot(&h, true).unwrap();
            assert_eq!(snap.k(), rep.k, "{m:?} snapshot k");
        }
        assert_eq!(reg.len(), 6);
        // metrics-style listing sees all of them
        let listed = reg.list();
        assert_eq!(listed.len(), 6);
        reg.shutdown();
    }
}
