//! Hosted approximation artifacts: named, query-only read replicas of
//! stored [`StoredArtifact`]s (`POST /artifacts/load`).
//!
//! Unlike live sessions, a loaded artifact has no actor thread — it is
//! immutable shared state, so queries from any number of connection
//! threads read it concurrently through an `Arc` with no serialization
//! point. This is the "store-and-serve" half of the system: a session
//! computes and saves a factorization once, and any number of servers
//! can reload it and answer out-of-sample extension queries without the
//! original dataset or kernel oracle.

use super::protocol::MAX_ARTIFACTS;
use super::registry::lock;
use crate::nystrom::StoredArtifact;
use crate::util::json::Json;
use crate::Result;
use crate::{anyhow, bail};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One hosted artifact: the immutable stored approximation plus serving
/// bookkeeping.
pub struct HostedArtifact {
    pub name: String,
    pub artifact: StoredArtifact,
    /// Raw client path it was loaded from (display only).
    pub loaded_from: PathBuf,
    /// Query points answered against this artifact.
    pub queries: AtomicU64,
    /// Most recent task model fit against this artifact (same reuse
    /// pattern as [`SessionShared::task_cache`](super::registry::SessionShared)).
    pub task_cache: Mutex<Option<super::registry::CachedTask>>,
}

impl HostedArtifact {
    /// Status object for `GET /artifacts[/{name}]` and `/metrics`.
    /// (`Json::Obj` is a BTreeMap, so key order in the response is
    /// alphabetical regardless of insertion order.)
    pub fn status_json(&self) -> Json {
        let mut fields = match self.artifact.summary_json() {
            Json::Obj(m) => m,
            _ => Default::default(),
        };
        fields.insert("name".to_string(), Json::Str(self.name.clone()));
        fields.insert(
            "loaded_from".to_string(),
            Json::Str(self.loaded_from.display().to_string()),
        );
        fields.insert(
            "queries".to_string(),
            Json::Num(self.queries.load(Ordering::Relaxed) as f64),
        );
        Json::Obj(fields)
    }
}

/// Named loaded artifacts (the query-only sibling of the session
/// [`Registry`](super::registry::Registry)).
#[derive(Default)]
pub struct ArtifactRegistry {
    inner: Mutex<HashMap<String, Arc<HostedArtifact>>>,
    counter: AtomicU64,
}

impl ArtifactRegistry {
    pub fn new() -> ArtifactRegistry {
        ArtifactRegistry::default()
    }

    /// Host an artifact under `name` (auto-generated `aN` when absent).
    pub fn insert(
        &self,
        name: Option<String>,
        artifact: StoredArtifact,
        loaded_from: PathBuf,
    ) -> Result<Arc<HostedArtifact>> {
        let mut map = lock(&self.inner);
        if map.len() >= MAX_ARTIFACTS {
            bail!(
                "artifact cap reached ({MAX_ARTIFACTS} loaded) — unload one \
                 first (DELETE /artifacts/{{name}})"
            );
        }
        let name = match name {
            Some(n) => {
                if map.contains_key(&n) {
                    bail!("artifact '{n}' already exists");
                }
                n
            }
            None => loop {
                let candidate =
                    format!("a{}", self.counter.fetch_add(1, Ordering::Relaxed));
                if !map.contains_key(&candidate) {
                    break candidate;
                }
            },
        };
        let hosted = Arc::new(HostedArtifact {
            name: name.clone(),
            artifact,
            loaded_from,
            queries: AtomicU64::new(0),
            task_cache: Mutex::new(None),
        });
        map.insert(name, hosted.clone());
        Ok(hosted)
    }

    pub fn get(&self, name: &str) -> Option<Arc<HostedArtifact>> {
        lock(&self.inner).get(name).cloned()
    }

    pub fn remove(&self, name: &str) -> Option<Arc<HostedArtifact>> {
        lock(&self.inner).remove(name)
    }

    /// Every hosted artifact, name-sorted.
    pub fn list(&self) -> Vec<Arc<HostedArtifact>> {
        let mut out: Vec<_> = lock(&self.inner).values().cloned().collect();
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }

    pub fn len(&self) -> usize {
        lock(&self.inner).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Find a duplicate-name conflict without inserting (for a clean 409
    /// like the session registry's create path).
    pub fn contains(&self, name: &str) -> bool {
        lock(&self.inner).contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::Gaussian;
    use crate::nystrom::store::Provenance;
    use crate::sampling::{assemble_from_indices, ImplicitOracle};

    fn artifact() -> StoredArtifact {
        let ds = two_moons(30, 0.05, 3);
        let kern = Gaussian::new(0.6);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let approx = assemble_from_indices(&oracle, vec![0, 7, 21], 0.0);
        StoredArtifact::from_parts(
            approx,
            &ds,
            &kern,
            Provenance { source: "test".into(), method: "oASIS".into() },
            None,
        )
        .unwrap()
    }

    #[test]
    fn insert_get_remove_and_auto_names() {
        let reg = ArtifactRegistry::new();
        let a =
            reg.insert(Some("x".into()), artifact(), PathBuf::from("x.oasis"));
        assert_eq!(a.unwrap().name, "x");
        assert!(reg
            .insert(Some("x".into()), artifact(), PathBuf::from("x.oasis"))
            .is_err());
        let auto = reg.insert(None, artifact(), PathBuf::from("y.oasis")).unwrap();
        assert_eq!(auto.name, "a0");
        assert_eq!(reg.len(), 2);
        let names: Vec<_> =
            reg.list().iter().map(|h| h.name.clone()).collect();
        assert_eq!(names, vec!["a0", "x"]);
        assert!(reg.get("x").is_some());
        assert!(reg.remove("x").is_some());
        assert!(reg.get("x").is_none());
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn status_json_shape() {
        let reg = ArtifactRegistry::new();
        let h = reg
            .insert(Some("s".into()), artifact(), PathBuf::from("s.oasis"))
            .unwrap();
        h.queries.fetch_add(3, Ordering::Relaxed);
        let j = h.status_json();
        assert_eq!(j.get("name").and_then(Json::as_str), Some("s"));
        assert_eq!(j.get("k").and_then(Json::as_usize), Some(3));
        assert_eq!(j.get("n").and_then(Json::as_usize), Some(30));
        assert_eq!(j.get("queries").and_then(Json::as_usize), Some(3));
        assert_eq!(j.get("method").and_then(Json::as_str), Some("oASIS"));
    }
}
