//! Wire-format parsing for `oasis serve`: request payloads are decoded
//! into the [`engine`](crate::engine) layer's spec types (the same
//! [`RunSpec`] the CLI builds from flags), validated, and handed to the
//! registry; JSON serialization helpers shared by the handlers live here
//! too. The endpoint-by-endpoint protocol reference is in the
//! [`server`](crate::server) module docs.
//!
//! Every parser here validates before constructing — sampler
//! constructors `assert!` on bad arguments, and a panic inside a
//! connection or actor thread would drop the request without a response,
//! so malformed input must be rejected with a clean 400 first.

use crate::data::LoadLimits;
use crate::linalg::Mat;
use crate::sampling::{StoppingCriterion, StoppingRule};
use crate::util::json::Json;
use crate::Result;
use crate::{anyhow, bail};
use std::path::{Component, Path, PathBuf};
use std::time::Duration;

pub use crate::engine::{
    DatasetSpec, KernelSpec, Method, MethodSpec, RunSpec, WarmStartSpec,
};

/// Serving-sanity caps: request bodies are already bounded
/// ([`MAX_BODY_BYTES`](super::http::MAX_BODY_BYTES)), so a tiny request
/// must not be able to trigger an unbounded server-side allocation or
/// thread storm either. Generous for real workloads, fatal for abuse.
pub const MAX_DATASET_N: usize = 2_000_000;
pub const MAX_DATASET_DIM: usize = 4_096;
pub const MAX_WORKERS: usize = 256;
/// Cap on generated-dataset storage n × dim (100e6 f64 ≈ 800 MB) —
/// checked against [`crate::data::generators::dim_by_name`] *before*
/// allocating.
pub const MAX_DATASET_ELEMS: u128 = 100_000_000;
/// Residual-materializing methods (`farahat`, `adaptive-random`) hold a
/// dense n×n matrix; cap their n (16_384² × 8 B ≈ 2.1 GB).
pub const MAX_RESIDUAL_N: usize = 16_384;
/// Cap on n × max_cols session state (C plus W⁻¹ working sets;
/// 200e6 f64 ≈ 1.6 GB).
pub const MAX_STATE_ELEMS: u128 = 200_000_000;
/// Cap on factor elements shipped by `?factors=1` responses: the JSON
/// tree costs ~3× the matrix itself, so a legal-sized session's factors
/// could otherwise OOM the server on serialization alone (10e6 numbers
/// ≈ a 200 MB response).
pub const MAX_FACTOR_ELEMS: usize = 10_000_000;
/// Cap on concurrently hosted loaded artifacts.
pub const MAX_ARTIFACTS: usize = 256;

/// The dataset caps above as [`LoadLimits`], so file-backed datasets are
/// bounded *while they parse* — a tiny `{"file": …}` request must not be
/// able to materialize an arbitrarily large file into server memory.
pub fn serving_load_limits() -> LoadLimits {
    LoadLimits {
        max_n: MAX_DATASET_N,
        max_dim: MAX_DATASET_DIM,
        max_elems: MAX_DATASET_ELEMS,
    }
}

/// Resolve a client-supplied path under the server's `--fs-root`:
/// relative paths only, no `..` (or root/prefix) components, and the
/// deepest *existing* ancestor must canonicalize to somewhere inside
/// the canonicalized root (a symlink inside the root pointing outside
/// it would otherwise defeat the lexical checks) — the filesystem the
/// server will touch is exactly the subtree the operator pointed it at.
pub fn resolve_fs_path(root: &Path, raw: &str) -> Result<PathBuf> {
    if raw.is_empty() {
        bail!("'path' must be a non-empty relative path");
    }
    let p = Path::new(raw);
    if p.is_absolute() {
        bail!("'path' must be relative (it resolves under the server's --fs-root)");
    }
    for comp in p.components() {
        match comp {
            Component::Normal(_) | Component::CurDir => {}
            _ => bail!("'path' may not contain '..', root, or drive components"),
        }
    }
    let joined = root.join(p);
    let canon_root = root.canonicalize().map_err(|e| {
        anyhow!("server fs root {} is not resolvable: {e}", root.display())
    })?;
    // walk up to the deepest existing ancestor; the not-yet-existing
    // suffix is Normal-only (checked above), so it cannot escape later
    let mut probe: &Path = &joined;
    let canon = loop {
        match probe.canonicalize() {
            Ok(c) => break c,
            Err(_) => {
                // an ancestor that *exists* but cannot canonicalize is a
                // dangling/cyclic symlink — writing through it would
                // create a file wherever it points, so refuse it rather
                // than fall back to its (in-root) parent
                if probe.symlink_metadata().is_ok() {
                    bail!(
                        "'path' passes through an unresolvable symlink ({})",
                        probe.display()
                    );
                }
                match probe.parent() {
                    Some(parent) if !parent.as_os_str().is_empty() => {
                        probe = parent
                    }
                    // ran out of ancestors (relative root like "."): the
                    // root itself is the deepest existing ancestor
                    _ => break canon_root.clone(),
                }
            }
        }
    };
    if !canon.starts_with(&canon_root) {
        bail!(
            "'path' escapes the server's --fs-root via a symlink ({})",
            probe.display()
        );
    }
    Ok(joined)
}

/// Parsed `POST /sessions` payload: an optional hosting name plus the
/// engine [`RunSpec`] every front end shares. The spec types themselves
/// (dataset/kernel/method, warm start, shard reads) live in
/// [`crate::engine`] and are re-exported above; this module only parses
/// JSON into them.
#[derive(Clone, Debug)]
pub struct CreateRequest {
    pub name: Option<String>,
    pub spec: RunSpec,
}

/// Parsed `POST /sessions/{name}/step` payload.
#[derive(Clone, Debug)]
pub struct StepRequest {
    /// Maximum number of `step()` calls in this batch.
    pub steps: usize,
    /// Extra any-of stopping criteria evaluated before every step.
    pub rule: StoppingRule,
    /// Enqueue on the session's actor thread and return 202 immediately.
    pub background: bool,
}

/// Parsed `POST /sessions/{name}/query` payload.
#[derive(Clone, Debug)]
pub struct QueryRequest {
    pub points: Vec<Vec<f64>>,
    /// Row indices i for which to return ĝ(z, i).
    pub targets: Vec<usize>,
    /// Take a fresh snapshot instead of reusing the cached one.
    pub refresh: bool,
}

/// Parse a request body as a JSON object; an empty body means `{}`.
pub fn parse_body(body: &str) -> Result<Json> {
    let trimmed = body.trim();
    if trimmed.is_empty() {
        return Ok(Json::Obj(Default::default()));
    }
    let j = Json::parse(trimmed).map_err(|e| anyhow!("invalid JSON body: {e}"))?;
    if j.as_obj().is_none() {
        bail!("request body must be a JSON object");
    }
    Ok(j)
}

/// Field access that treats an explicit JSON `null` as absent — clients
/// that serialize unset options as `null` must not trip presence checks
/// (a `"deadline_ms": null` must not become a zero deadline).
fn field<'a>(j: &'a Json, key: &str) -> Option<&'a Json> {
    match j.get(key) {
        None | Some(Json::Null) => None,
        Some(v) => Some(v),
    }
}

fn get_f64(j: &Json, key: &str, default: f64) -> Result<f64> {
    match field(j, key) {
        None => Ok(default),
        Some(v) => {
            let x = v
                .as_f64()
                .ok_or_else(|| anyhow!("'{key}' must be a number"))?;
            if !x.is_finite() {
                bail!("'{key}' must be finite");
            }
            Ok(x)
        }
    }
}

fn get_usize(j: &Json, key: &str, default: usize) -> Result<usize> {
    match field(j, key) {
        None => Ok(default),
        Some(v) => {
            let x = v
                .as_f64()
                .ok_or_else(|| anyhow!("'{key}' must be a number"))?;
            if !x.is_finite() || x < 0.0 || x.fract() != 0.0 || x > 1e15 {
                bail!("'{key}' must be a non-negative integer");
            }
            Ok(x as usize)
        }
    }
}

fn get_u64(j: &Json, key: &str, default: u64) -> Result<u64> {
    Ok(get_usize(j, key, default as usize)? as u64)
}

fn get_bool(j: &Json, key: &str, default: bool) -> Result<bool> {
    match field(j, key) {
        None => Ok(default),
        Some(v) => v
            .as_bool()
            .ok_or_else(|| anyhow!("'{key}' must be a boolean")),
    }
}

fn get_str(j: &Json, key: &str, default: &str) -> Result<String> {
    match field(j, key) {
        None => Ok(default.to_string()),
        Some(v) => v
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| anyhow!("'{key}' must be a string")),
    }
}

/// Session names appear in URLs and thread names: short and URL-safe.
fn validate_name(name: &str) -> Result<()> {
    if name.is_empty() || name.len() > 64 {
        bail!("session name must be 1–64 characters");
    }
    if !name
        .chars()
        .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
    {
        bail!("session name may only contain [A-Za-z0-9._-]");
    }
    Ok(())
}

fn parse_dataset(j: &Json, fs_root: &Path) -> Result<DatasetSpec> {
    let d = match j.get("dataset") {
        None => {
            return Ok(DatasetSpec::Generator {
                name: "two-moons".into(),
                n: 2000,
                seed: 7,
                noise: 0.05,
                dim: 0,
            })
        }
        Some(d) => d,
    };
    if d.as_obj().is_none() {
        bail!("'dataset' must be an object");
    }
    if let Some(file) = field(d, "file") {
        let raw = file
            .as_str()
            .ok_or_else(|| anyhow!("'dataset.file' must be a string path"))?;
        if raw.is_empty() {
            bail!("'dataset.file' must be a non-empty path");
        }
        if d.get("points").is_some() {
            bail!("'dataset' may give 'file' or 'points', not both");
        }
        // resolved (and sandbox-checked) under --fs-root right here, so
        // an unresolved client path never exists in a parsed request;
        // `label` keeps the raw spelling for provenance — the server's
        // filesystem layout must not leak into artifacts or listings
        let path = resolve_fs_path(fs_root, raw)
            .map_err(|e| e.wrap("'dataset.file'"))?;
        return Ok(DatasetSpec::File { label: raw.to_string(), path });
    }
    if let Some(points) = d.get("points") {
        let arr = points
            .as_arr()
            .ok_or_else(|| anyhow!("'dataset.points' must be an array"))?;
        if arr.is_empty() {
            bail!("'dataset.points' must not be empty");
        }
        let mut rows = Vec::with_capacity(arr.len());
        let mut dim = None;
        for (i, row) in arr.iter().enumerate() {
            let row = row
                .as_arr()
                .ok_or_else(|| anyhow!("point {i} must be an array of numbers"))?;
            let mut out = Vec::with_capacity(row.len());
            for v in row {
                let x = v
                    .as_f64()
                    .ok_or_else(|| anyhow!("point {i} has a non-number entry"))?;
                if !x.is_finite() {
                    bail!("point {i} has a non-finite entry");
                }
                out.push(x);
            }
            match dim {
                None => {
                    if out.is_empty() {
                        bail!("points must have dimension ≥ 1");
                    }
                    dim = Some(out.len());
                }
                Some(d) if d != out.len() => {
                    bail!("point {i} has dimension {} but point 0 has {d}", out.len())
                }
                _ => {}
            }
            rows.push(out);
        }
        return Ok(DatasetSpec::Points(rows));
    }
    let n = get_usize(d, "n", 2000)?;
    if n == 0 || n > MAX_DATASET_N {
        bail!("'dataset.n' must be in 1..={MAX_DATASET_N}");
    }
    let dim = get_usize(d, "dim", 0)?;
    if dim > MAX_DATASET_DIM {
        bail!("'dataset.dim' must be ≤ {MAX_DATASET_DIM}");
    }
    Ok(DatasetSpec::Generator {
        name: get_str(d, "generator", "two-moons")?,
        n,
        seed: get_u64(d, "seed", 7)?,
        noise: get_f64(d, "noise", 0.05)?,
        dim,
    })
}

fn parse_kernel(j: &Json) -> Result<KernelSpec> {
    let k = match j.get("kernel") {
        None => {
            return Ok(KernelSpec::Gaussian { sigma: None, sigma_fraction: 0.05 })
        }
        Some(k) => k,
    };
    if k.as_obj().is_none() {
        bail!("'kernel' must be an object");
    }
    let t = get_str(k, "type", "gaussian")?;
    Ok(match t.as_str() {
        "gaussian" => {
            let sigma = match field(k, "sigma") {
                None => None,
                Some(v) => {
                    let s = v
                        .as_f64()
                        .ok_or_else(|| anyhow!("'kernel.sigma' must be a number"))?;
                    if !(s.is_finite() && s > 0.0) {
                        bail!("'kernel.sigma' must be > 0");
                    }
                    Some(s)
                }
            };
            let frac = get_f64(k, "sigma_fraction", 0.05)?;
            if !(frac > 0.0) {
                bail!("'kernel.sigma_fraction' must be > 0");
            }
            KernelSpec::Gaussian { sigma, sigma_fraction: frac }
        }
        "linear" => KernelSpec::Linear,
        "laplacian" => {
            let sigma = get_f64(k, "sigma", 1.0)?;
            if !(sigma > 0.0) {
                bail!("'kernel.sigma' must be > 0");
            }
            KernelSpec::Laplacian { sigma }
        }
        "polynomial" => KernelSpec::Polynomial {
            degree: get_usize(k, "degree", 2)?.min(64) as u32,
            offset: get_f64(k, "offset", 1.0)?,
        },
        other => bail!(
            "unknown kernel type '{other}' (expected gaussian|linear|\
             laplacian|polynomial)"
        ),
    })
}

/// Parse a `POST /sessions` body into a [`CreateRequest`]. `fs_root` is
/// the server's `--fs-root`; `dataset.file` and `warm_start` paths are
/// resolved (and sandbox-checked) under it right here, so no caller can
/// forget to.
pub fn parse_create(body: &str, fs_root: &Path) -> Result<CreateRequest> {
    let j = parse_body(body)?;
    let name = match field(&j, "name") {
        None => None,
        Some(v) => {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow!("'name' must be a string"))?;
            validate_name(s)?;
            Some(s.to_string())
        }
    };
    let dataset = parse_dataset(&j, fs_root)?;
    let kernel = parse_kernel(&j)?;
    let method = Method::parse(&get_str(&j, "method", "oasis")?)?;
    // reject one-shot methods before any dataset is materialized — they
    // have no resumable session to host
    if !method.has_session() {
        bail!(
            "method '{}' is one-shot and cannot be hosted as a session \
             (hostable: oasis|sis|farahat|icd|adaptive-random|oasis-p)",
            method.as_str()
        );
    }
    let max_cols = get_usize(&j, "max_cols", 450)?;
    if max_cols == 0 {
        bail!("'max_cols' must be ≥ 1");
    }
    let init_cols = get_usize(&j, "init_cols", 10.min(max_cols))?;
    if init_cols == 0 || init_cols > max_cols {
        bail!("'init_cols' must be in 1..=max_cols");
    }
    let tol = get_f64(&j, "tol", 1e-12)?;
    if tol < 0.0 {
        bail!("'tol' must be ≥ 0");
    }
    let batch = get_usize(&j, "batch", 10)?;
    if batch == 0 {
        bail!("'batch' must be ≥ 1");
    }
    let workers = get_usize(&j, "workers", 4)?;
    if workers == 0 || workers > MAX_WORKERS {
        bail!("'workers' must be in 1..={MAX_WORKERS}");
    }
    // oASIS-P SQUEAK-style merge width; 1 = the paper's exact protocol.
    // Capped well below max_cols-scale values — a huge batch only wastes
    // worker sweeps.
    let merge_batch = get_usize(&j, "merge_batch", 1)?;
    if merge_batch == 0 || merge_batch > 64 {
        bail!("'merge_batch' must be in 1..=64");
    }
    let warm_start = match field(&j, "warm_start") {
        None => None,
        Some(v) => {
            let raw = v
                .as_str()
                .ok_or_else(|| anyhow!("'warm_start' must be a string path"))?;
            let path = resolve_fs_path(fs_root, raw)
                .map_err(|e| e.wrap("'warm_start'"))?;
            Some(WarmStartSpec { label: raw.to_string(), path })
        }
    };
    Ok(CreateRequest {
        name,
        spec: RunSpec {
            dataset,
            kernel,
            method: MethodSpec {
                method,
                max_cols,
                init_cols,
                tol,
                seed: get_u64(&j, "seed", 7)?,
                batch,
                workers,
                merge_batch,
                listen: None,
            },
            // the server's stopping rules arrive per step request
            stopping: StoppingRule::new(),
            shard_reads: get_bool(&j, "shard_reads", false)?,
            warm_start,
        },
    })
}

/// Parse a `POST /sessions/{name}/step` body. Criteria are assembled in
/// the same order as the CLI (`target_err`, `deadline_ms`, `score_below`,
/// then `budget`) so the first-listed reason wins ties.
pub fn parse_step(body: &str) -> Result<StepRequest> {
    let j = parse_body(body)?;
    let mut rule = StoppingRule::new();
    if field(&j, "target_err").is_some() {
        let t = get_f64(&j, "target_err", 0.0)?; // finite or 400
        rule = rule.with(StoppingCriterion::ErrorBelow(t));
    }
    if field(&j, "deadline_ms").is_some() {
        let ms = get_u64(&j, "deadline_ms", 0)?;
        rule = rule.with(StoppingCriterion::Deadline(Duration::from_millis(ms)));
    }
    if field(&j, "score_below").is_some() {
        let s = get_f64(&j, "score_below", 0.0)?; // finite or 400
        rule = rule.with(StoppingCriterion::ScoreBelow(s));
    }
    let budget = match field(&j, "budget") {
        None => None,
        Some(_) => {
            let b = get_usize(&j, "budget", 0)?;
            rule = rule.with(StoppingCriterion::ColumnBudget(b));
            Some(b)
        }
    };
    // with an explicit budget the batch may run all the way to it; the
    // bare default is one step per request
    let default_steps = if budget.is_some() { 1_000_000 } else { 1 };
    let steps = get_usize(&j, "steps", default_steps)?;
    if steps == 0 || steps > 1_000_000 {
        bail!("'steps' must be in 1..=1000000");
    }
    Ok(StepRequest {
        steps,
        rule,
        background: get_bool(&j, "background", false)?,
    })
}

/// Parse a `POST /sessions/{name}/query` body.
pub fn parse_query(body: &str) -> Result<QueryRequest> {
    let j = parse_body(body)?;
    let points = match j.get("points") {
        None => bail!("'points' (array of points) is required"),
        Some(p) => parse_point_rows(p, "points")?,
    };
    if points.is_empty() {
        bail!("'points' must not be empty");
    }
    let targets = match j.get("targets") {
        None => Vec::new(),
        Some(t) => {
            let arr = t
                .as_arr()
                .ok_or_else(|| anyhow!("'targets' must be an array of indices"))?;
            let mut out = Vec::with_capacity(arr.len());
            for v in arr {
                match v.as_f64() {
                    Some(x) if x.is_finite() && x >= 0.0 && x.fract() == 0.0 => {
                        out.push(x as usize)
                    }
                    _ => bail!("'targets' entries must be non-negative integers"),
                }
            }
            out
        }
    };
    Ok(QueryRequest {
        points,
        targets,
        refresh: get_bool(&j, "refresh", false)?,
    })
}

/// Parsed `POST /sessions/{name}/save` payload.
#[derive(Clone, Debug)]
pub struct SaveRequest {
    /// Raw client path (resolved under `--fs-root` by the handler).
    pub path: String,
    /// Encode the factor payload as f32 (compact, lossy — see
    /// [`crate::nystrom::store`]'s precision caveat).
    pub f32_payload: bool,
}

/// Parse a `POST /sessions/{name}/save` body.
pub fn parse_save(body: &str) -> Result<SaveRequest> {
    let j = parse_body(body)?;
    let path = field(&j, "path")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("'path' (string) is required"))?
        .to_string();
    Ok(SaveRequest { path, f32_payload: get_bool(&j, "f32", false)? })
}

/// Where a task request's training labels come from.
#[derive(Clone, Debug)]
pub enum TaskLabels {
    /// `"labels": […]` — inline values (bounded by the body size cap),
    /// output-major: one column per output. The wire shape is either a
    /// flat numeric array (single output) or one row per data point
    /// (`[[y0a, y0b], …]`, transposed here).
    Inline(Vec<Vec<f64>>),
    /// `"labels_file": "y.csv"` — dataset file columns, resolved under
    /// `--fs-root` and loaded under the serving caps by the handler.
    File { label: String, path: PathBuf, cols: Vec<usize> },
}

/// Parsed `POST /sessions/{name}/task` / `POST /artifacts/{name}/task`
/// payload.
#[derive(Clone, Debug)]
pub struct TaskRequest {
    pub kind: crate::tasks::TaskKind,
    pub ridge: f64,
    pub components: usize,
    pub clusters: usize,
    pub seed: u64,
    pub labels: Option<TaskLabels>,
    /// Query points to predict for (may be empty: fit only).
    pub predict: Vec<Vec<f64>>,
    /// Serve predictions through the f32 path (krr only — see
    /// [`FittedTask::predict_f32`](crate::tasks::FittedTask::predict_f32)'s
    /// precision caveat).
    pub f32_predict: bool,
    /// Sessions only: take a fresh snapshot before fitting.
    pub refresh: bool,
}

/// Parse a task-endpoint body. Defaults mirror the CLI's `oasis task`
/// flags (`ridge` 1e-3, `components` 2 — or the cluster count for the
/// cluster task — `clusters` 2, `seed` 7).
pub fn parse_task(body: &str, fs_root: &Path) -> Result<TaskRequest> {
    let j = parse_body(body)?;
    let kind = crate::tasks::TaskKind::parse(&get_str(&j, "task", "krr")?)?;
    let ridge = get_f64(&j, "ridge", 1e-3)?;
    let clusters = get_usize(&j, "clusters", 2)?;
    let components =
        get_usize(&j, "components", kind.default_components(clusters))?;
    let seed = get_u64(&j, "seed", 7)?;
    let labels = match (field(&j, "labels"), field(&j, "labels_file")) {
        (Some(_), Some(_)) => {
            bail!("give 'labels' (inline) or 'labels_file', not both")
        }
        (Some(v), None) => Some(TaskLabels::Inline(parse_label_columns(v)?)),
        (None, Some(v)) => {
            let raw = v
                .as_str()
                .ok_or_else(|| anyhow!("'labels_file' must be a string path"))?;
            let path = resolve_fs_path(fs_root, raw)
                .map_err(|e| e.wrap("'labels_file'"))?;
            let cols = match (field(&j, "label_col"), field(&j, "label_cols")) {
                (Some(_), Some(_)) => {
                    bail!("give 'label_col' or 'label_cols', not both")
                }
                (None, Some(c)) => parse_label_cols_field(c)?,
                (_, None) => vec![get_usize(&j, "label_col", 0)?],
            };
            Some(TaskLabels::File { label: raw.to_string(), path, cols })
        }
        (None, None) => None,
    };
    let predict = match field(&j, "predict") {
        None => Vec::new(),
        Some(p) => parse_point_rows(p, "predict")?,
    };
    Ok(TaskRequest {
        kind,
        ridge,
        components,
        clusters,
        seed,
        labels,
        predict,
        f32_predict: get_bool(&j, "f32", false)?,
        refresh: get_bool(&j, "refresh", false)?,
    })
}

/// Inline `"labels"`: a flat numeric array (one output) or one numeric
/// row per data point (m outputs, every row the same width). Returned
/// output-major to match
/// [`TaskConfig::labels`](crate::tasks::TaskConfig).
fn parse_label_columns(v: &Json) -> Result<Vec<Vec<f64>>> {
    let arr = v.as_arr().ok_or_else(|| {
        anyhow!("'labels' must be an array of numbers or of per-point rows")
    })?;
    if arr.is_empty() {
        bail!("'labels' must not be empty");
    }
    if arr[0].as_arr().is_some() {
        let rows = parse_point_rows(v, "labels")?;
        let m = rows[0].len();
        if m == 0 {
            bail!("labels row 0 must have at least one output");
        }
        if let Some(i) = rows.iter().position(|r| r.len() != m) {
            bail!("labels row {i} has {} outputs but row 0 has {m}", rows[i].len());
        }
        // transpose: wire rows are per point, fits want per output
        Ok((0..m)
            .map(|j| rows.iter().map(|r| r[j]).collect())
            .collect())
    } else {
        let mut out = Vec::with_capacity(arr.len());
        for (i, l) in arr.iter().enumerate() {
            match l.as_f64() {
                Some(x) if x.is_finite() => out.push(x),
                _ => bail!("label {i} is not a finite number"),
            }
        }
        Ok(vec![out])
    }
}

/// `"label_cols"`: an array of column indices or the CLI's string
/// spelling (`"0,2-4"` — [`LabelsSpec::parse_cols`]).
fn parse_label_cols_field(v: &Json) -> Result<Vec<usize>> {
    use crate::engine::LabelsSpec;
    if let Some(s) = v.as_str() {
        return LabelsSpec::parse_cols(s);
    }
    let arr = v.as_arr().ok_or_else(|| {
        anyhow!("'label_cols' must be an array of column indices or a string")
    })?;
    if arr.is_empty() {
        bail!("'label_cols' must not be empty");
    }
    let mut out = Vec::with_capacity(arr.len());
    for c in arr {
        match c.as_f64() {
            Some(x) if x.is_finite() && x >= 0.0 && x.fract() == 0.0 => {
                out.push(x as usize)
            }
            _ => bail!("'label_cols' entries must be non-negative integers"),
        }
    }
    Ok(out)
}

/// Parse an array of numeric points (shared by the query and task
/// parsers).
fn parse_point_rows(v: &Json, what: &str) -> Result<Vec<Vec<f64>>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| anyhow!("'{what}' must be an array of points"))?;
    let mut points = Vec::with_capacity(arr.len());
    for (i, p) in arr.iter().enumerate() {
        let row = p
            .as_arr()
            .ok_or_else(|| anyhow!("{what} point {i} must be an array"))?;
        let mut out = Vec::with_capacity(row.len());
        for x in row {
            let x = x
                .as_f64()
                .ok_or_else(|| anyhow!("{what} point {i} has a non-number entry"))?;
            if !x.is_finite() {
                bail!("{what} point {i} has a non-finite entry");
            }
            out.push(x);
        }
        points.push(out);
    }
    Ok(points)
}

/// Parsed `POST /artifacts/load` payload.
#[derive(Clone, Debug)]
pub struct ArtifactLoadRequest {
    /// Raw client path (resolved under `--fs-root` by the handler).
    pub path: String,
    /// Hosting name; auto-generated (`aN`) when absent.
    pub name: Option<String>,
}

/// Parse a `POST /artifacts/load` body.
pub fn parse_artifact_load(body: &str) -> Result<ArtifactLoadRequest> {
    let j = parse_body(body)?;
    let path = field(&j, "path")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("'path' (string) is required"))?
        .to_string();
    let name = match field(&j, "name") {
        None => None,
        Some(v) => {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow!("'name' must be a string"))?;
            validate_name(s)?;
            Some(s.to_string())
        }
    };
    Ok(ArtifactLoadRequest { path, name })
}

pub fn usize_arr(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

pub fn num_arr(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

pub fn opt_num(x: Option<f64>) -> Json {
    match x {
        Some(v) => Json::Num(v),
        None => Json::Null,
    }
}

/// `{"rows": r, "cols": c, "data": [row-major flat]}`.
pub fn mat_json(m: &Mat) -> Json {
    Json::obj(vec![
        ("rows", Json::Num(m.rows as f64)),
        ("cols", Json::Num(m.cols as f64)),
        ("data", num_arr(&m.data)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `parse_create` with a benign fs root (tests that exercise the
    /// sandbox itself build their own root).
    fn pc(body: &str) -> crate::Result<CreateRequest> {
        parse_create(body, Path::new("."))
    }

    #[test]
    fn create_defaults() {
        let req = pc("{}").unwrap();
        assert!(req.name.is_none());
        assert_eq!(req.spec.method.method, Method::Oasis);
        assert_eq!(req.spec.method.max_cols, 450);
        assert_eq!(req.spec.method.init_cols, 10);
        assert!(!req.spec.shard_reads);
        assert!(req.spec.warm_start.is_none());
        assert!(req.spec.stopping.criteria().is_empty());
        match req.spec.dataset {
            DatasetSpec::Generator { ref name, n, .. } => {
                assert_eq!(name, "two-moons");
                assert_eq!(n, 2000);
            }
            _ => panic!("expected generator default"),
        }
        match req.spec.kernel {
            KernelSpec::Gaussian { sigma: None, sigma_fraction } => {
                assert_eq!(sigma_fraction, 0.05)
            }
            ref k => panic!("unexpected kernel {k:?}"),
        }
    }

    #[test]
    fn create_full_payload() {
        let body = r#"{
            "name": "train-7",
            "dataset": {"generator": "two-moons", "n": 300, "seed": 42},
            "kernel": {"type": "gaussian", "sigma_fraction": 0.1},
            "method": "farahat",
            "max_cols": 40, "init_cols": 3, "tol": 1e-10, "seed": 5
        }"#;
        let req = pc(body).unwrap();
        assert_eq!(req.name.as_deref(), Some("train-7"));
        assert_eq!(req.spec.method.method, Method::Farahat);
        assert_eq!(req.spec.method.max_cols, 40);
        assert_eq!(req.spec.method.seed, 5);
    }

    #[test]
    fn create_inline_points() {
        let body = r#"{"dataset": {"points": [[0,0],[1,0],[0,1]]}}"#;
        let req = pc(body).unwrap();
        match req.spec.dataset {
            DatasetSpec::Points(ref rows) => {
                assert_eq!(rows.len(), 3);
                assert_eq!(rows[1], vec![1.0, 0.0]);
            }
            _ => panic!("expected inline points"),
        }
        let ds = req.spec.dataset.build(&serving_load_limits()).unwrap();
        assert_eq!((ds.n(), ds.dim()), (3, 2));
    }

    #[test]
    fn create_parses_warm_start_and_shard_reads() {
        let req = pc(
            r#"{"method": "oasis-p",
                "dataset": {"file": "train.mat"},
                "warm_start": "models/seed.oasis",
                "shard_reads": true}"#,
        )
        .unwrap();
        assert!(req.spec.shard_reads);
        let ws = req.spec.warm_start.as_ref().expect("warm start parsed");
        assert_eq!(ws.label, "models/seed.oasis");
        assert!(ws.path.ends_with("models/seed.oasis"));
        // paths resolve under --fs-root like every other client path
        assert!(pc(r#"{"warm_start": "../outside.oasis"}"#).is_err());
        assert!(pc(r#"{"warm_start": "/abs.oasis"}"#).is_err());
        // null means absent, like every other option
        assert!(pc(r#"{"warm_start": null, "shard_reads": null}"#)
            .unwrap()
            .spec
            .warm_start
            .is_none());
    }

    /// One request must not be able to abort the server with an
    /// unbounded allocation or thread storm.
    #[test]
    fn create_enforces_serving_caps() {
        assert!(pc(r#"{"dataset": {"n": 1e9}}"#).is_err());
        assert!(pc(r#"{"dataset": {"dim": 100000}}"#).is_err());
        assert!(pc(r#"{"workers": 100000}"#).is_err());
        // at the cap is fine
        assert!(pc(&format!(
            r#"{{"dataset": {{"n": {MAX_DATASET_N}}}, "workers": {MAX_WORKERS}}}"#
        ))
        .is_ok());
        // n and dim individually legal but n×dim over the element cap is
        // rejected at build time, before any allocation
        let big = pc(
            r#"{"dataset": {"generator": "mnist", "n": 200000, "dim": 4096}}"#,
        )
        .unwrap();
        assert!(big.spec.dataset.build(&serving_load_limits()).is_err());
        // …while the same generator at sane scale builds
        let ok = pc(r#"{"dataset": {"generator": "mnist", "n": 50}}"#)
            .unwrap();
        assert_eq!(
            ok.spec.dataset.build(&serving_load_limits()).unwrap().dim(),
            784
        );
    }

    #[test]
    fn create_rejects_bad_input() {
        assert!(pc("not json").is_err());
        assert!(pc(r#"{"name": "has space"}"#).is_err());
        assert!(pc(r#"{"method": "magic"}"#).is_err());
        // one-shot methods parse in the engine but are not hostable —
        // refused here, before any dataset could be materialized
        for m in ["random", "leverage", "kmeans"] {
            let err = pc(&format!(r#"{{"method": "{m}"}}"#)).unwrap_err();
            assert!(format!("{err}").contains("one-shot"), "{err}");
        }
        assert!(pc(r#"{"max_cols": 0}"#).is_err());
        assert!(pc(r#"{"max_cols": 5, "init_cols": 9}"#).is_err());
        assert!(pc(r#"{"dataset": {"points": [[1,2],[3]]}}"#).is_err());
        assert!(pc(r#"{"dataset": {"points": []}}"#).is_err());
        assert!(pc(r#"{"kernel": {"type": "gaussian", "sigma": -1}}"#)
            .is_err());
        assert!(pc(r#"{"dataset": {"generator": "nope"}}"#)
            .map(|r| r.spec.dataset.build(&serving_load_limits()))
            .unwrap()
            .is_err());
    }

    #[test]
    fn step_defaults_and_rule_order() {
        let s = parse_step("").unwrap();
        assert_eq!(s.steps, 1);
        assert!(s.rule.criteria().is_empty());
        assert!(!s.background);

        let s = parse_step(
            r#"{"steps": 25, "target_err": 0.1, "deadline_ms": 500,
                "budget": 80, "background": true}"#,
        )
        .unwrap();
        assert_eq!(s.steps, 25);
        assert!(s.background);
        assert_eq!(
            s.rule.criteria(),
            &[
                StoppingCriterion::ErrorBelow(0.1),
                StoppingCriterion::Deadline(Duration::from_millis(500)),
                StoppingCriterion::ColumnBudget(80),
            ]
        );
    }

    #[test]
    fn step_budget_without_steps_runs_to_budget() {
        let s = parse_step(r#"{"budget": 30}"#).unwrap();
        assert_eq!(s.steps, 1_000_000);
        assert_eq!(s.rule.criteria(), &[StoppingCriterion::ColumnBudget(30)]);
    }

    /// Clients that serialize unset options as `null` must get the same
    /// behavior as omitting them — not a zero deadline/budget that stops
    /// the batch before its first step.
    #[test]
    fn step_null_fields_mean_absent() {
        let s = parse_step(
            r#"{"steps": 9, "deadline_ms": null, "budget": null,
                "target_err": null, "score_below": null}"#,
        )
        .unwrap();
        assert_eq!(s.steps, 9);
        assert!(s.rule.criteria().is_empty());
    }

    #[test]
    fn file_dataset_and_artifact_payloads_parse() {
        let req = pc(r#"{"dataset": {"file": "sets/train.csv"}}"#)
            .unwrap();
        match req.spec.dataset {
            DatasetSpec::File { ref label, ref path } => {
                assert_eq!(label, "sets/train.csv");
                // resolved under the (benign) test root, raw spelling kept
                assert!(path.ends_with("sets/train.csv"), "{}", path.display());
                assert_eq!(req.spec.dataset.describe(), "file:sets/train.csv");
            }
            other => panic!("expected file spec, got {other:?}"),
        }
        assert!(pc(r#"{"dataset": {"file": ""}}"#).is_err());
        assert!(pc(
            r#"{"dataset": {"file": "a.csv", "points": [[1]]}}"#
        )
        .is_err());

        let s = parse_save(r#"{"path": "out/model.oasis"}"#).unwrap();
        assert_eq!(s.path, "out/model.oasis");
        assert!(parse_save("{}").is_err());

        let l = parse_artifact_load(r#"{"path": "m.oasis", "name": "prod"}"#)
            .unwrap();
        assert_eq!((l.path.as_str(), l.name.as_deref()), ("m.oasis", Some("prod")));
        assert!(parse_artifact_load(r#"{"path": "m", "name": "bad name"}"#)
            .is_err());
    }

    /// Client paths must stay inside the server's `--fs-root` subtree —
    /// lexically and through symlinks.
    #[test]
    fn fs_path_resolution_rejects_escapes() {
        let root = std::env::temp_dir()
            .join("oasis-fsroot-test")
            .join(format!("r{}", std::process::id()));
        std::fs::create_dir_all(root.join("a")).unwrap();
        // existing subdirectory, and a file that does not exist yet
        // (the save path) both resolve under the root
        assert!(resolve_fs_path(&root, "a/b.csv")
            .unwrap()
            .ends_with("a/b.csv"));
        assert!(resolve_fs_path(&root, "fresh.oasis").is_ok());
        assert!(resolve_fs_path(&root, "new-dir/deep/fresh.oasis").is_ok());
        assert!(resolve_fs_path(&root, "").is_err());
        assert!(resolve_fs_path(&root, "/etc/passwd").is_err());
        assert!(resolve_fs_path(&root, "../outside").is_err());
        assert!(resolve_fs_path(&root, "a/../../outside").is_err());
        // a nonexistent root is refused outright
        assert!(resolve_fs_path(&root.join("absent"), "x").is_err());
        // a symlink inside the root pointing outside it must not let a
        // request through the sandbox — whether its target exists
        // (canonicalizes outside) or not (dangling: a save would write
        // through it)
        #[cfg(unix)]
        {
            let link = root.join("esc");
            std::fs::remove_file(&link).ok();
            std::os::unix::fs::symlink("/", &link).unwrap();
            let err = resolve_fs_path(&root, "esc/etc/passwd").unwrap_err();
            assert!(format!("{err}").contains("symlink"), "{err}");
            let dangling = root.join("dangle");
            std::fs::remove_file(&dangling).ok();
            std::os::unix::fs::symlink(
                root.join("absent-target-far-away"),
                &dangling,
            )
            .unwrap();
            let err = resolve_fs_path(&root, "dangle").unwrap_err();
            assert!(format!("{err}").contains("symlink"), "{err}");
        }
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn task_payloads_parse() {
        use crate::tasks::TaskKind;
        let root = Path::new(".");
        // defaults
        let t = parse_task("{}", root).unwrap();
        assert_eq!(t.kind, TaskKind::Krr);
        assert_eq!(t.ridge, 1e-3);
        assert_eq!(t.components, 2);
        assert!(t.labels.is_none());
        assert!(t.predict.is_empty());
        assert!(!t.refresh);
        // cluster components default to the cluster count
        let t = parse_task(r#"{"task":"cluster","clusters":5}"#, root).unwrap();
        assert_eq!(t.kind, TaskKind::Cluster);
        assert_eq!(t.components, 5);
        // full krr payload with inline labels + predictions
        let t = parse_task(
            r#"{"task":"krr","ridge":0.01,"labels":[0,1,0.5],
                "predict":[[0.1,0.2],[1,2]],"refresh":true}"#,
            root,
        )
        .unwrap();
        assert_eq!(t.ridge, 0.01);
        match &t.labels {
            Some(TaskLabels::Inline(v)) => {
                assert_eq!(v, &vec![vec![0.0, 1.0, 0.5]])
            }
            other => panic!("unexpected labels {other:?}"),
        }
        assert_eq!(t.predict.len(), 2);
        assert!(t.refresh);
        assert!(!t.f32_predict);
        // multi-output inline labels arrive per point and transpose to
        // output-major columns
        let t = parse_task(
            r#"{"task":"krr","labels":[[0,10],[1,20],[0.5,30]],"f32":true}"#,
            root,
        )
        .unwrap();
        match &t.labels {
            Some(TaskLabels::Inline(v)) => assert_eq!(
                v,
                &vec![vec![0.0, 1.0, 0.5], vec![10.0, 20.0, 30.0]]
            ),
            other => panic!("unexpected labels {other:?}"),
        }
        assert!(t.f32_predict);
        // labels_file resolves under fs-root, with a column selector
        let t = parse_task(
            r#"{"labels_file":"y/train.csv","label_col":3}"#,
            root,
        )
        .unwrap();
        match &t.labels {
            Some(TaskLabels::File { label, path, cols }) => {
                assert_eq!(label, "y/train.csv");
                assert!(path.ends_with("y/train.csv"));
                assert_eq!(cols, &vec![3]);
            }
            other => panic!("unexpected labels {other:?}"),
        }
        // label_cols: an index array or the CLI's range spelling
        let t = parse_task(
            r#"{"labels_file":"y.csv","label_cols":[0,2]}"#,
            root,
        )
        .unwrap();
        match &t.labels {
            Some(TaskLabels::File { cols, .. }) => {
                assert_eq!(cols, &vec![0, 2])
            }
            other => panic!("unexpected labels {other:?}"),
        }
        let t = parse_task(
            r#"{"labels_file":"y.csv","label_cols":"1-3"}"#,
            root,
        )
        .unwrap();
        match &t.labels {
            Some(TaskLabels::File { cols, .. }) => {
                assert_eq!(cols, &vec![1, 2, 3])
            }
            other => panic!("unexpected labels {other:?}"),
        }
        // rejections: unknown task, both label sources, escapes, bad rows
        assert!(parse_task(r#"{"task":"magic"}"#, root).is_err());
        assert!(parse_task(
            r#"{"labels":[1],"labels_file":"y.csv"}"#,
            root
        )
        .is_err());
        assert!(parse_task(r#"{"labels_file":"../y.csv"}"#, root).is_err());
        assert!(parse_task(r#"{"labels":[1,"x"]}"#, root).is_err());
        assert!(parse_task(r#"{"labels":[]}"#, root).is_err());
        assert!(parse_task(r#"{"labels":[[1,2],[3]]}"#, root).is_err());
        assert!(parse_task(r#"{"predict":[[1,null]]}"#, root).is_err());
        assert!(parse_task(
            r#"{"labels_file":"y.csv","label_col":0,"label_cols":[1]}"#,
            root
        )
        .is_err());
        assert!(parse_task(
            r#"{"labels_file":"y.csv","label_cols":[]}"#,
            root
        )
        .is_err());
        assert!(parse_task(r#"{"f32":"yes"}"#, root).is_err());
    }

    #[test]
    fn save_parses_f32_flag() {
        let s = parse_save(r#"{"path":"m.oasis"}"#).unwrap();
        assert!(!s.f32_payload);
        let s = parse_save(r#"{"path":"m.oasis","f32":true}"#).unwrap();
        assert!(s.f32_payload);
        assert!(parse_save(r#"{"path":"m.oasis","f32":3}"#).is_err());
    }

    #[test]
    fn query_parses_points_and_targets() {
        let q = parse_query(r#"{"points": [[0.5, 0.5]], "targets": [0, 7]}"#)
            .unwrap();
        assert_eq!(q.points, vec![vec![0.5, 0.5]]);
        assert_eq!(q.targets, vec![0, 7]);
        assert!(!q.refresh);
        assert!(parse_query("{}").is_err());
        assert!(parse_query(r#"{"points": [[1], [2]], "targets": [-1]}"#).is_err());
    }
}
