//! Minimal HTTP/1.1 framing over std TCP — just enough for the JSON
//! protocol documented in [`super`]: request line + headers +
//! `Content-Length` bodies, keep-alive by default, no chunked encoding,
//! no TLS. Deliberately dependency-free so the tier-1 gate stays offline.

use std::collections::BTreeMap;
use std::io::{BufRead, Read, Write};

/// Cap on request bodies. Inline datasets can be sizable, but a bound
/// keeps one connection from exhausting server memory.
pub const MAX_BODY_BYTES: usize = 64 * 1024 * 1024;

/// Cap on one request/header line — same rationale as [`MAX_BODY_BYTES`]:
/// `read_line` alone would grow without limit on a newline-free stream.
pub const MAX_LINE_BYTES: usize = 8 * 1024;

/// Cap on header count per request.
pub const MAX_HEADERS: usize = 64;

/// A parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path without the query string.
    pub path: String,
    /// Query-string pairs (`?a=1&b`); no percent-decoding is applied —
    /// the protocol only uses flag-like parameters.
    pub query: BTreeMap<String, String>,
    /// Header map, keys lowercased.
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn body_str(&self) -> std::borrow::Cow<'_, str> {
        String::from_utf8_lossy(&self.body)
    }

    /// Did the client ask to close the connection after this exchange?
    pub fn wants_close(&self) -> bool {
        self.headers
            .get("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false)
    }

    /// Path split into non-empty segments (`/sessions/a/step` →
    /// `["sessions", "a", "step"]`).
    pub fn segments(&self) -> Vec<&str> {
        self.path.split('/').filter(|s| !s.is_empty()).collect()
    }

    /// A boolean flag given either as a query parameter (`?key`,
    /// `?key=1`, `?key=true`) or as a boolean body field.
    pub fn flag(&self, body: &crate::util::json::Json, key: &str) -> bool {
        if let Some(v) = self.query.get(key) {
            return v.is_empty() || v == "1" || v == "true";
        }
        body.get(key)
            .and_then(crate::util::json::Json::as_bool)
            .unwrap_or(false)
    }
}

fn bad(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

/// Read one `\n`-terminated line (without the `\r\n`), bounded by
/// [`MAX_LINE_BYTES`]. `Ok(None)` on clean EOF before any byte.
fn read_line_capped<R: BufRead>(reader: &mut R) -> std::io::Result<Option<String>> {
    let mut line: Vec<u8> = Vec::new();
    loop {
        let buf = reader.fill_buf()?;
        if buf.is_empty() {
            return if line.is_empty() {
                Ok(None)
            } else {
                Err(bad("eof mid-line"))
            };
        }
        match buf.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                line.extend_from_slice(&buf[..pos]);
                reader.consume(pos + 1);
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                if line.len() > MAX_LINE_BYTES {
                    return Err(bad("line too long"));
                }
                return Ok(Some(String::from_utf8_lossy(&line).into_owned()));
            }
            None => {
                let len = buf.len();
                line.extend_from_slice(buf);
                reader.consume(len);
                if line.len() > MAX_LINE_BYTES {
                    return Err(bad("line too long"));
                }
            }
        }
    }
}

/// Read one request off the connection, answering `Expect: 100-continue`
/// with the interim response on `writer` before reading the body (curl
/// sends the header for bodies over ~1 KB — inline-points datasets —
/// and would otherwise stall waiting for it). `Ok(None)` on clean EOF
/// before a request line (the peer closed a kept-alive connection).
pub fn read_request<R: BufRead, W: Write>(
    reader: &mut R,
    writer: &mut W,
) -> std::io::Result<Option<Request>> {
    let line = match read_line_capped(reader)? {
        None => return Ok(None),
        Some(l) => l,
    };
    let mut parts = line.split_whitespace();
    let (method, target) = match (parts.next(), parts.next()) {
        (Some(m), Some(t)) => (m.to_string(), t.to_string()),
        _ => return Err(bad("malformed request line")),
    };
    let mut headers = BTreeMap::new();
    let mut header_lines = 0usize;
    loop {
        let h = match read_line_capped(reader)? {
            None => return Err(bad("eof inside headers")),
            Some(h) => h,
        };
        if h.is_empty() {
            break;
        }
        header_lines += 1;
        if header_lines > MAX_HEADERS {
            return Err(bad("too many headers"));
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    let len: usize = match headers.get("content-length") {
        None => 0,
        Some(v) => v.parse().map_err(|_| bad("bad content-length"))?,
    };
    if len > MAX_BODY_BYTES {
        return Err(bad("request body too large"));
    }
    if headers
        .get("expect")
        .map(|v| v.eq_ignore_ascii_case("100-continue"))
        .unwrap_or(false)
    {
        write!(writer, "HTTP/1.1 100 Continue\r\n\r\n")?;
        writer.flush()?;
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    let (path, query_str) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    let mut query = BTreeMap::new();
    for pair in query_str.split('&').filter(|s| !s.is_empty()) {
        match pair.split_once('=') {
            Some((k, v)) => query.insert(k.to_string(), v.to_string()),
            None => query.insert(pair.to_string(), String::new()),
        };
    }
    Ok(Some(Request { method, path, query, headers, body }))
}

/// Minimal one-shot client: one request on a fresh `Connection: close`
/// connection, returning `(status, body)`. The server never calls this —
/// it exists so the integration tests and `examples/serve_client.rs`
/// share one wire-level client instead of drifting copies.
pub fn client_request(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> std::io::Result<(u16, String)> {
    let mut stream = std::net::TcpStream::connect(addr)?;
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: client\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw)?;
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| bad("no status line in response"))?;
    let at = raw
        .find("\r\n\r\n")
        .ok_or_else(|| bad("no header terminator in response"))?
        + 4;
    Ok((status, raw[at..].to_string()))
}

/// A persistent keep-alive client connection: many request/response
/// exchanges on one socket, amortizing the TCP (and thread-pool
/// dispatch) setup across requests. This is what `oasis bench-serve`'s
/// load generator and the integration tests drive; [`client_request`]
/// remains the one-shot `Connection: close` variant.
#[derive(Debug)]
pub struct ClientConn {
    stream: std::net::TcpStream,
    reader: std::io::BufReader<std::net::TcpStream>,
}

impl ClientConn {
    pub fn connect(addr: std::net::SocketAddr) -> std::io::Result<ClientConn> {
        let stream = std::net::TcpStream::connect(addr)?;
        // request/response exchanges are latency-bound: never Nagle-delay
        // a small request body
        let _ = stream.set_nodelay(true);
        let reader = std::io::BufReader::new(stream.try_clone()?);
        Ok(ClientConn { stream, reader })
    }

    /// One exchange on the kept-alive connection → `(status, body)`.
    /// Errors when the server closed the connection (e.g. after a
    /// `Connection: close` response or an idle timeout) — reconnect and
    /// retry at the caller's discretion.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: &str,
    ) -> std::io::Result<(u16, String)> {
        let (status, _, body) =
            self.request_with_headers(method, path, &[], body)?;
        Ok((status, body))
    }

    /// One exchange with explicit extra request headers, returning the
    /// response headers too (keys lowercased) — what the request-
    /// correlation tests use to assert on `X-Request-Id`.
    pub fn request_with_headers(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &str,
    ) -> std::io::Result<(u16, BTreeMap<String, String>, String)> {
        let mut extra = String::new();
        for (k, v) in headers {
            extra.push_str(&format!("{k}: {v}\r\n"));
        }
        write!(
            self.stream,
            "{method} {path} HTTP/1.1\r\nHost: client\r\n{extra}\
             Content-Length: {}\r\n\r\n{body}",
            body.len()
        )?;
        self.stream.flush()?;
        read_response_full(&mut self.reader)
    }
}

/// Read one framed `(status, body)` response off a kept-alive
/// connection. Only `Content-Length` framing is understood — which is
/// all the server emits.
fn read_response<R: BufRead>(reader: &mut R) -> std::io::Result<(u16, String)> {
    let (status, _, body) = read_response_full(reader)?;
    Ok((status, body))
}

/// [`read_response`] that also returns the response headers (keys
/// lowercased).
fn read_response_full<R: BufRead>(
    reader: &mut R,
) -> std::io::Result<(u16, BTreeMap<String, String>, String)> {
    let line = read_line_capped(reader)?
        .ok_or_else(|| bad("peer closed before the status line"))?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| bad("no status in response line"))?;
    let mut headers = BTreeMap::new();
    let mut len = 0usize;
    loop {
        let h = read_line_capped(reader)?
            .ok_or_else(|| bad("eof inside response headers"))?;
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            let key = k.trim().to_ascii_lowercase();
            let val = v.trim().to_string();
            if key == "content-length" {
                len = val
                    .parse()
                    .map_err(|_| bad("bad response content-length"))?;
            }
            headers.insert(key, val);
        }
    }
    if len > MAX_BODY_BYTES {
        return Err(bad("response body too large"));
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    Ok((status, headers, String::from_utf8_lossy(&body).into_owned()))
}

/// An HTTP response carrying a JSON (or, for the Prometheus exposition,
/// plain-text) body.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub body: String,
    /// `Content-Type` header value (JSON unless built via
    /// [`Response::text`]).
    pub content_type: &'static str,
    /// Additional response headers (`X-Request-Id` correlation).
    pub extra_headers: Vec<(&'static str, String)>,
}

impl Response {
    pub fn json(status: u16, body: crate::util::json::Json) -> Response {
        Response {
            status,
            body: body.to_string(),
            content_type: "application/json",
            extra_headers: Vec::new(),
        }
    }

    /// A non-JSON body with an explicit content type (the Prometheus
    /// text exposition).
    pub fn text(status: u16, content_type: &'static str, body: String) -> Response {
        Response { status, body, content_type, extra_headers: Vec::new() }
    }

    /// Attach one extra response header.
    pub fn with_header(mut self, name: &'static str, value: String) -> Response {
        self.extra_headers.push((name, value));
        self
    }

    pub fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            202 => "Accepted",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            410 => "Gone",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }

    pub fn write_to<W: Write>(&self, w: &mut W, close: bool) -> std::io::Result<()> {
        let mut extra = String::new();
        for (k, v) in &self.extra_headers {
            extra.push_str(&format!("{k}: {v}\r\n"));
        }
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\n\
             Content-Length: {}\r\nConnection: {}\r\n{extra}\r\n{}",
            self.status,
            self.reason(),
            self.content_type,
            self.body.len(),
            if close { "close" } else { "keep-alive" },
            self.body,
        )?;
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(raw: &str) -> Request {
        read_request(&mut BufReader::new(raw.as_bytes()), &mut std::io::sink())
            .unwrap()
            .unwrap()
    }

    #[test]
    fn parses_request_with_body_and_query() {
        let raw = "POST /sessions/a/step?factors=1&x HTTP/1.1\r\n\
                   Host: localhost\r\nContent-Length: 11\r\n\r\n{\"steps\":3}";
        let req = parse(raw);
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/sessions/a/step");
        assert_eq!(req.segments(), vec!["sessions", "a", "step"]);
        assert_eq!(req.query.get("factors").map(String::as_str), Some("1"));
        assert_eq!(req.query.get("x").map(String::as_str), Some(""));
        assert_eq!(req.body_str(), "{\"steps\":3}");
        assert!(!req.wants_close());
    }

    #[test]
    fn keep_alive_reads_sequential_requests() {
        let raw = "GET /healthz HTTP/1.1\r\n\r\n\
                   GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut reader = BufReader::new(raw.as_bytes());
        let mut sink = std::io::sink();
        let a = read_request(&mut reader, &mut sink).unwrap().unwrap();
        assert_eq!(a.path, "/healthz");
        let b = read_request(&mut reader, &mut sink).unwrap().unwrap();
        assert_eq!(b.path, "/metrics");
        assert!(b.wants_close());
        assert!(read_request(&mut reader, &mut sink).unwrap().is_none()); // EOF
    }

    #[test]
    fn truncated_body_errors() {
        let raw = "POST /x HTTP/1.1\r\nContent-Length: 50\r\n\r\nshort";
        let err = read_request(
            &mut BufReader::new(raw.as_bytes()),
            &mut std::io::sink(),
        );
        assert!(err.is_err());
    }

    /// `Expect: 100-continue` gets the interim response before the body
    /// is read (curl sends it for bodies over ~1 KB).
    #[test]
    fn expect_100_continue_is_answered() {
        let raw = "POST /sessions HTTP/1.1\r\nExpect: 100-continue\r\n\
                   Content-Length: 2\r\n\r\n{}";
        let mut interim: Vec<u8> = Vec::new();
        let req = read_request(&mut BufReader::new(raw.as_bytes()), &mut interim)
            .unwrap()
            .unwrap();
        assert_eq!(req.body_str(), "{}");
        assert_eq!(
            String::from_utf8(interim).unwrap(),
            "HTTP/1.1 100 Continue\r\n\r\n"
        );
    }

    /// Header framing is bounded: an over-long line or an unbounded
    /// header list must error instead of growing memory.
    #[test]
    fn oversized_lines_and_header_floods_rejected() {
        let mut sink = std::io::sink();
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_LINE_BYTES + 1));
        assert!(
            read_request(&mut BufReader::new(long.as_bytes()), &mut sink).is_err()
        );
        // a newline-free stream longer than the cap errors too
        let endless = "G".repeat(MAX_LINE_BYTES + 2);
        assert!(read_request(&mut BufReader::new(endless.as_bytes()), &mut sink)
            .is_err());
        let mut flood = String::from("GET / HTTP/1.1\r\n");
        for i in 0..(MAX_HEADERS + 2) {
            flood.push_str(&format!("X-{i}: v\r\n"));
        }
        flood.push_str("\r\n");
        assert!(read_request(&mut BufReader::new(flood.as_bytes()), &mut sink)
            .is_err());
    }

    #[test]
    fn response_framing() {
        let mut out = Vec::new();
        Response::json(
            200,
            crate::util::json::Json::obj(vec![(
                "ok",
                crate::util::json::Json::Bool(true),
            )]),
        )
        .write_to(&mut out, true)
        .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 11"), "{text}");
        assert!(text.contains("Connection: close"), "{text}");
        assert!(text.ends_with("{\"ok\":true}"), "{text}");
    }

    #[test]
    fn client_reads_sequential_keep_alive_responses() {
        let raw = "HTTP/1.1 200 OK\r\nContent-Type: application/json\r\n\
                   Content-Length: 11\r\nConnection: keep-alive\r\n\r\n\
                   {\"ok\":true}\
                   HTTP/1.1 429 Too Many Requests\r\nContent-Length: 0\r\n\r\n";
        let mut reader = BufReader::new(raw.as_bytes());
        let (status, body) = read_response(&mut reader).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "{\"ok\":true}");
        let (status, body) = read_response(&mut reader).unwrap();
        assert_eq!(status, 429);
        assert!(body.is_empty());
        assert!(read_response(&mut reader).is_err()); // EOF between frames
    }

    #[test]
    fn extra_headers_are_emitted_before_the_body() {
        let mut out = Vec::new();
        Response::json(200, crate::util::json::Json::Bool(true))
            .with_header("X-Request-Id", "r-7".to_string())
            .write_to(&mut out, false)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("X-Request-Id: r-7\r\n"), "{text}");
        let head = text.split("\r\n\r\n").next().unwrap();
        assert!(head.contains("X-Request-Id"), "header in head: {text}");
        // response headers round-trip through the client parser
        let mut reader = BufReader::new(text.as_bytes());
        let (status, headers, body) = read_response_full(&mut reader).unwrap();
        assert_eq!(status, 200);
        assert_eq!(headers.get("x-request-id").map(String::as_str), Some("r-7"));
        assert_eq!(body, "true");
    }

    #[test]
    fn overload_statuses_have_reasons() {
        for (status, reason) in
            [(429, "Too Many Requests"), (503, "Service Unavailable")]
        {
            let r = Response::json(status, crate::util::json::Json::Null);
            assert_eq!(r.reason(), reason);
        }
    }

    #[test]
    fn text_responses_carry_their_content_type() {
        let mut out = Vec::new();
        Response::text(200, "text/plain; version=0.0.4; charset=utf-8", "x 1\n".into())
            .write_to(&mut out, true)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(
            text.contains("Content-Type: text/plain; version=0.0.4; charset=utf-8"),
            "{text}"
        );
        assert!(text.ends_with("x 1\n"), "{text}");
    }
}
