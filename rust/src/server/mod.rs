//! `oasis serve` — a long-lived approximation server hosting concurrent,
//! resumable sampler sessions over HTTP/1.1 + JSON.
//!
//! The paper's core claim is that oASIS selection is cheap *per step*
//! (§III), and PR 1 turned every sampler into a resumable
//! [`SamplerSession`](crate::sampling::SamplerSession) precisely so an
//! approximation can be **grown** over time instead of recomputed. This
//! module is the serving layer on top: a registry of named sessions, each
//! hosted on its own actor thread ([`registry`]), grown a few columns per
//! request, snapshotted and queried while it runs, and evicted when the
//! caller is done. The server is dependency-free — std `TcpListener`
//! ([`http`]) and the crate's own JSON ([`crate::util::json`]).
//!
//! ```no_run
//! use oasis::server::Server;
//! let server = Server::bind("127.0.0.1:7437").unwrap();
//! println!("listening on http://{}", server.local_addr().unwrap());
//! server.run().unwrap(); // serves until POST /shutdown
//! ```
//!
//! # Protocol reference
//!
//! Every request and response body is JSON (`Content-Type:
//! application/json`); errors are `{"error": "<message>"}` with a 4xx/5xx
//! status. Boolean options can be sent either as body fields or as query
//! parameters (`?factors=1`).
//!
//! ## `POST /sessions` — create a session
//!
//! ```json
//! {
//!   "name": "train-7",                 // optional; auto-generated "sN"
//!   "dataset": {                        // optional; default two-moons
//!     "generator": "two-moons",         // or abalone|borg|mnist|salinas|
//!                                       //    lightfield|tiny-images
//!     "n": 2000, "seed": 7,
//!     "noise": 0.05,                    // two-moons only
//!     "dim": 0                          // 0 = generator default
//!   },
//!   // …or inline data: "dataset": {"points": [[x0,…], [x1,…], …]}
//!   // …or a file:      "dataset": {"file": "sets/train.csv"}
//!   //    (CSV or oasis-matrix binary; the path resolves under the
//!   //     server's --fs-root and may not escape it)
//!   "kernel": {                         // optional; default gaussian
//!     "type": "gaussian",               // or linear|laplacian|polynomial
//!     "sigma": 0.5,                     // explicit σ…
//!     "sigma_fraction": 0.05            // …or fraction of max distance
//!   },
//!   "method": "oasis",                  // or sis|farahat|icd|
//!                                       //    adaptive-random|oasis-p
//!   "max_cols": 450, "init_cols": 10,   // sampler parameters
//!   "tol": 1e-12, "seed": 7,
//!   "batch": 10,                        // adaptive-random only
//!   "workers": 4,                       // oasis-p only
//!   "merge_batch": 1,                   // oasis-p only (1..=64): SQUEAK
//!                                       //   merge width — candidates
//!                                       //   admitted per argmax round.
//!                                       //   1 (default) is the exact
//!                                       //   sequential protocol; >1
//!                                       //   trades selection order for
//!                                       //   fewer gather rounds. Session
//!                                       //   stats gain a "workers" array
//!                                       //   of per-worker counters.
//!   "warm_start": "models/seed.oasis",  // optional (oasis|sis methods):
//!                                       //   resume selection from a
//!                                       //   stored artifact's Λ — the
//!                                       //   session starts at the
//!                                       //   artifact's k and extends
//!                                       //   it. The run's dataset/
//!                                       //   kernel must match the
//!                                       //   artifact's (checked). For
//!                                       //   a *bit-exact* resume, also
//!                                       //   pass the init_cols the
//!                                       //   recording run used (not
//!                                       //   stored in the artifact; a
//!                                       //   different split is still a
//!                                       //   valid resume, just not
//!                                       //   bitwise). Path resolves
//!                                       //   under --fs-root.
//!   "shard_reads": false                // optional (oasis-p + a binary
//!                                       //   dataset file): each worker
//!                                       //   reads only its own byte
//!                                       //   range of the file; the
//!                                       //   server holds no full
//!                                       //   dataset (queries and saves
//!                                       //   use the selected points
//!                                       //   mirrored from the leader).
//!                                       //   Needs a kernel that
//!                                       //   resolves without data
//!                                       //   (e.g. explicit sigma).
//! }
//! ```
//!
//! The create payload *is* an [`engine::RunSpec`](crate::engine::RunSpec)
//! in JSON: the parser ([`protocol`]) decodes into the same spec types
//! the CLI builds from flags, and the registry resolves them through the
//! same [`engine::SessionBuilder`](crate::engine::SessionBuilder) — which
//! is why a server-hosted run is bit-identical to the equivalent CLI run.
//!
//! → `{"name", "method", "n", "dim", "k", "error_estimate"}`. `409` if the
//! name exists. Note `farahat` and `adaptive-random` materialize the full
//! n×n residual at creation — use them for explicit-scale datasets only.
//! Serving-sanity caps apply (see [`protocol`]'s `MAX_*` constants):
//! dataset size, dimensionality, worker count, n×n-residual methods, and
//! n×max_cols session state are all bounded so one request cannot abort
//! the server with an oversized allocation.
//!
//! ## `POST /sessions/{name}/step` — grow the approximation
//!
//! ```json
//! {
//!   "steps": 25,            // max selections this batch (default 1, or
//!                           // unbounded if "budget" is given)
//!   "target_err": 1e-3,     // optional any-of stopping criteria,
//!   "deadline_ms": 500,     // evaluated before every step in this
//!   "score_below": 1e-9,    // order (first match names the stop)
//!   "budget": 450,          // total-k cap (counts seed columns)
//!   "background": false     // true → 202 now, work proceeds on the
//!                           // session's actor thread
//! }
//! ```
//!
//! → `{"name", "k", "stepped", "error_estimate", "secs", "stop"?}` where
//! `stop` ∈ `budget|score-tol|error-target|deadline|exhausted` when the
//! batch ended early. Steps on one session serialize in arrival order;
//! different sessions step in parallel.
//!
//! ## `GET /sessions/{name}/snapshot` — current factors, mid-run
//!
//! Options: `factors` (include `"c"`/`"winv"` as
//! `{"rows","cols","data"}`), `cached` (reuse the last snapshot instead
//! of gathering a fresh one). → `{"name", "n", "k", "indices",
//! "error_estimate", "selection_secs", "c"?, "winv"?}`. The run can keep
//! stepping afterwards — snapshots are consistent prefixes.
//!
//! ## `POST /sessions/{name}/query` — out-of-sample extension
//!
//! ```json
//! {"points": [[x,…], …], "targets": [0, 17], "refresh": false}
//! ```
//!
//! For each query point z the server computes `b = k(z, x_Λ)` against the
//! live snapshot's selected points and returns the Nyström extension
//! weights `w = W⁻¹ b` (length k), plus `ĝ(z, i) = wᵀC(i,:)` for each
//! requested target row. Only the k selected points are touched — O(k²)
//! per point. `refresh` forces a fresh snapshot first; otherwise the
//! cached one is reused across queries.
//!
//! → `{"name", "snapshot_k", "results": [{"weights": […], "kernel": […]?}]}`
//!
//! ## `POST /sessions/{name}/task` — fit + run a downstream task
//!
//! ```json
//! {
//!   "task": "krr",              // krr|kpca|cluster (default krr)
//!   "ridge": 1e-3,              // krr regularization λ > 0
//!   "components": 2,            // kpca/cluster embedding dims
//!   "clusters": 2,              // cluster count (cluster task)
//!   "seed": 7,                  // cluster k-means seeding
//!   "labels": [0, 1, 0, …],     // krr training labels, inline…
//!   "labels_file": "y.csv",     // …or a dataset file column (resolves
//!   "label_col": 0,             //    under --fs-root; default col 0)
//!   "predict": [[x,…], …],      // points to predict for (optional)
//!   "refresh": false            // fresh snapshot before fitting
//! }
//! ```
//!
//! Fits the task on the session's current snapshot — KRR dual weights,
//! kernel-PCA eigenpairs, or spectral k-means — in O(nk²), never
//! materializing the n×n matrix, and predicts for the given points by
//! evaluating the kernel against the k selected points only. Identical
//! consecutive requests reuse the cached fitted model (`"model":
//! "cached"`; see the `tasks_fitted`/`task_cache_hits`/
//! `task_predictions` counters in `/metrics`), and a krr request
//! **without** labels reuses the session's cached fitted model when it
//! is a krr model — fit once with labels, then serve predict-only
//! traffic without re-shipping or re-reading the label set. (The cache
//! holds one model per session: fitting a different task in between
//! evicts it, and the next label-free krr request is a 400 until
//! labels are shipped again.)
//!
//! → the fit summary (`{"task", "k", …}` — e.g. `ridge`+`train_rmse`
//! for krr, `eigenvalues` for kpca, `clusters` for cluster) plus
//! `{"name", "model": "fitted"|"cached", "predictions"?}` where
//! `predictions` is one value (krr), embedding vector (kpca), or
//! cluster label (cluster) per point — rendered by the same serializer
//! as `oasis task --json`, so front-end answers are byte-comparable.
//!
//! ## `POST /artifacts/{name}/task` — downstream task, dataset-free
//!
//! Same payload and response as the session task endpoint, but fit on a
//! loaded artifact's stored factors and answered from its k stored
//! selected points — no dataset, no oracle (`refresh` is ignored). A
//! `krr` request **without** labels reuses the fitted model persisted
//! in the artifact's task section, if any (`"model": "stored"`) — the
//! `sample → save → fit → predict` pipeline's serving end.
//!
//! ## `POST /sessions/{name}/save` — persist the approximation
//!
//! ```json
//! {"path": "models/train-7.oasis", "f32": false}
//! ```
//!
//! Takes a fresh snapshot of the (still-running) session and writes it
//! as a versioned artifact file — indices, `C`, `W⁻¹`, the k selected
//! points, resolved kernel parameters, dataset provenance, and the
//! current error estimate, checksummed (format documented in
//! [`crate::nystrom::store`]). `"f32": true` stores the `C`/`W⁻¹`
//! payload in f32 (half the bytes; lossy — reloaded factors, queries,
//! and task fits then carry f32 precision, while the selected points
//! stay f64-exact). The path resolves under `--fs-root`
//! (relative, no `..`). → `{"name", "path", "n", "k", "bytes"}`. The
//! session keeps running; save again later for a bigger artifact.
//!
//! ## `POST /artifacts/load` — host a stored artifact
//!
//! ```json
//! {"path": "models/train-7.oasis", "name": "prod"}   // name optional ("aN")
//! ```
//!
//! Loads and verifies an artifact file and hosts it as a **query-only**
//! read replica: no actor thread, immutable, any number of concurrent
//! queries. → the artifact status object (`{"name", "n", "k", "dim",
//! "kernel", "method", "source", "error_estimate", …}`). `409` if the
//! name exists; `400` for corrupt/truncated/wrong-version files.
//!
//! ## `POST /artifacts/{name}/query` — query without the original data
//!
//! Same payload and response shape as the session query (`points` +
//! optional `targets`), but answered entirely from the stored factors
//! and the k stored selected points — the original dataset and kernel
//! oracle are not needed (`refresh` is meaningless here and ignored).
//!
//! ## Other endpoints
//!
//! | endpoint | effect |
//! |---|---|
//! | `GET /sessions` | `{"sessions": [status…]}` (name-sorted) |
//! | `GET /sessions/{name}` | status: `k`, `busy`, `steps_done`, `error_estimate`, `step_latency`, `stop`?, `failed`? |
//! | `POST /sessions/{name}/finish` (or `DELETE /sessions/{name}`) | final factors + eviction; options: `factors` |
//! | `GET /artifacts` | `{"artifacts": [status…]}` (name-sorted) |
//! | `GET /artifacts/{name}` | one artifact's status (incl. `queries` served) |
//! | `DELETE /artifacts/{name}` | unload a hosted artifact |
//! | `GET /metrics` | `{"uptime_secs", "start_time_unix_secs", "version", "server": counters, "sessions": […], "artifacts": […]}` |
//! | `GET /healthz` | `{"ok": true, "uptime_secs", "start_time_unix_secs", "version"}` |
//! | `POST /shutdown` | stop accepting, tear down all sessions |
//!
//! ## Observability
//!
//! Every latency the server reports is a log₂-bucketed histogram
//! ([`crate::obs::hist`]) carrying `count`/`mean_ms`/`last_ms`/`max_ms`
//! **plus** `p50_ms`/`p90_ms`/`p99_ms` quantile estimates: the
//! per-session `step_latency` in the status/metrics JSON, and
//! per-endpoint request durations recorded around every dispatched
//! request (labels are normalized — `POST /sessions/train-7/step`
//! records under `POST /sessions/{name}/step`, so the label set stays
//! bounded).
//!
//! `GET /metrics` additionally serves **Prometheus text exposition**
//! (version 0.0.4) when asked — via the query parameter
//! `?format=prometheus`, or an `Accept` header mentioning `text/plain`
//! or `openmetrics`:
//!
//! ```bash
//! curl localhost:7437/metrics?format=prometheus
//! curl -H 'Accept: text/plain' localhost:7437/metrics
//! ```
//!
//! The page carries `oasis_build_info{version=…}`,
//! `oasis_start_time_seconds`, `oasis_uptime_seconds`, every JSON
//! counter as an `oasis_*_total` counter, request durations as
//! cumulative `oasis_http_request_duration_seconds_bucket{endpoint=…}`
//! histogram series (`_sum`/`_count` included), per-session step
//! histograms (`oasis_session_steps_total`,
//! `oasis_session_step_duration_seconds`, `oasis_session_columns`,
//! `oasis_session_error_estimate`), and — for live distributed
//! (oasis-p) sessions — per-worker gauges scraped mid-run
//! (`oasis_worker_heartbeat_age_seconds`, `oasis_worker_reshards_total`,
//! `oasis_worker_wire_bytes_total`, …) labeled
//! `{session="…", worker="…"}`. `oasis promcheck --port P` scrapes and
//! validates a page end to end ([`crate::obs::prom::validate`] — the CI
//! smoke jobs run exactly that). JSON remains the default rendering and
//! is unchanged apart from the added fields above.
//!
//! ## Consistency guarantees
//!
//! A session's selection sequence is bit-identical to the equivalent
//! offline run (`session(...)` + `run_to_completion`) with the same
//! dataset/kernel/method parameters: the server adds no randomness and
//! every snapshot is a consistent k-column prefix of that sequence —
//! which is what the socket-level acceptance test in
//! `rust/tests/server.rs` asserts.

pub mod artifacts;
pub mod handlers;
pub mod http;
pub mod metrics;
pub mod protocol;
pub mod registry;

pub use artifacts::ArtifactRegistry;
pub use http::{Request, Response};
pub use metrics::ServerMetrics;
pub use registry::{Registry, SessionHandle};

use crate::Result;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Operator-side server configuration (CLI flags, not request payloads).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Root directory under which every client-supplied path (dataset
    /// `{"file": …}`, artifact save/load) resolves; clients cannot reach
    /// outside it (see [`protocol::resolve_fs_path`]).
    pub fs_root: PathBuf,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig { fs_root: PathBuf::from(".") }
    }
}

/// Shared server state: the session registry, hosted artifacts,
/// counters, and the stop flag.
pub struct ServerState {
    pub registry: Registry,
    pub artifacts: ArtifactRegistry,
    pub config: ServerConfig,
    pub metrics: ServerMetrics,
    pub started: Instant,
    /// Wall-clock start time (Unix seconds), for
    /// `oasis_start_time_seconds` and `/healthz` — the monotonic
    /// [`started`](ServerState::started) clock drives `uptime_secs`.
    pub start_unix_secs: f64,
    stop: AtomicBool,
}

impl ServerState {
    fn new(config: ServerConfig) -> ServerState {
        let start_unix_secs = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0);
        ServerState {
            registry: Registry::new(),
            artifacts: ArtifactRegistry::new(),
            config,
            metrics: ServerMetrics::default(),
            started: Instant::now(),
            start_unix_secs,
            stop: AtomicBool::new(false),
        }
    }

    pub fn stopping(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    /// Ask the accept loop to exit (what `POST /shutdown` does).
    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

/// The `oasis serve` server: a bound listener plus shared state.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
}

impl Server {
    /// Bind (e.g. `"127.0.0.1:7437"`, or port `0` for an ephemeral port —
    /// read it back with [`local_addr`](Server::local_addr)) with the
    /// default configuration (`fs_root` = current directory).
    pub fn bind(addr: &str) -> Result<Server> {
        Server::bind_with(addr, ServerConfig::default())
    }

    /// Bind with an explicit [`ServerConfig`].
    pub fn bind_with(addr: &str, config: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(addr)?;
        // non-blocking accept so the stop flag is polled between peers
        listener.set_nonblocking(true)?;
        Ok(Server { listener, state: Arc::new(ServerState::new(config)) })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// A handle to the shared state (for in-process callers/tests: request
    /// a stop, inspect metrics, drive the registry directly).
    pub fn state(&self) -> Arc<ServerState> {
        self.state.clone()
    }

    /// Serve until [`ServerState::request_stop`] (usually `POST
    /// /shutdown`), then tear down every session. One thread per
    /// connection; connections are kept alive until the peer closes or
    /// sends `Connection: close`.
    pub fn run(self) -> Result<()> {
        let mut consecutive_errors = 0u32;
        loop {
            // checked every iteration — a stream of incoming connections
            // must not postpone shutdown past the current accept
            if self.state.stopping() {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    consecutive_errors = 0;
                    ServerMetrics::inc(&self.state.metrics.connections);
                    // accepted sockets must block; the listener's
                    // non-blocking flag is not inherited on all platforms
                    let _ = stream.set_nonblocking(false);
                    let state = self.state.clone();
                    std::thread::spawn(move || handle_conn(stream, state));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    consecutive_errors = 0;
                    if self.state.stopping() {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    // transient accept errors (a peer that RSTs before
                    // accept → ECONNABORTED, fd exhaustion → EMFILE) must
                    // not take down every hosted session; back off and
                    // retry, giving up only on persistent failure
                    if self.state.stopping() {
                        break;
                    }
                    consecutive_errors += 1;
                    if consecutive_errors >= 100 {
                        self.state.registry.shutdown();
                        return Err(e.into());
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
        self.state.registry.shutdown();
        Ok(())
    }
}

/// One connection: read requests until EOF/close, dispatch each.
fn handle_conn(stream: TcpStream, state: Arc<ServerState>) {
    // bound idle keep-alive connections
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader_stream);
    let mut writer = stream;
    loop {
        match http::read_request(&mut reader, &mut writer) {
            Ok(Some(req)) => {
                let t0 = Instant::now();
                let resp = handlers::route(&state, &req);
                state.metrics.observe_request(
                    &handlers::endpoint_label(&req),
                    t0.elapsed().as_secs_f64(),
                );
                // check the stop flag *after* routing so /shutdown closes
                // its own connection
                let close = req.wants_close() || state.stopping();
                if resp.write_to(&mut writer, close).is_err() || close {
                    return;
                }
            }
            Ok(None) => return, // peer closed between requests
            Err(e) => {
                // an idle keep-alive connection hitting the read timeout
                // is closed silently — writing an unsolicited 400 here
                // could be mistaken for the response to the client's next
                // pipelined request
                let idle = matches!(
                    e.kind(),
                    std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
                );
                if !idle {
                    let resp = Response::json(
                        400,
                        crate::util::json::Json::obj(vec![(
                            "error",
                            crate::util::json::Json::Str(
                                "malformed HTTP request".into(),
                            ),
                        )]),
                    );
                    let _ = resp.write_to(&mut writer, true);
                }
                return;
            }
        }
    }
}
