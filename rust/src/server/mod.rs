//! `oasis serve` — a long-lived approximation server hosting concurrent,
//! resumable sampler sessions over HTTP/1.1 + JSON.
//!
//! The paper's core claim is that oASIS selection is cheap *per step*
//! (§III), and PR 1 turned every sampler into a resumable
//! [`SamplerSession`](crate::sampling::SamplerSession) precisely so an
//! approximation can be **grown** over time instead of recomputed. This
//! module is the serving layer on top: a registry of named sessions, each
//! hosted on its own actor thread ([`registry`]), grown a few columns per
//! request, snapshotted and queried while it runs, and evicted when the
//! caller is done. The server is dependency-free — std `TcpListener`
//! ([`http`]) and the crate's own JSON ([`crate::util::json`]).
//!
//! ```no_run
//! use oasis::server::Server;
//! let server = Server::bind("127.0.0.1:7437").unwrap();
//! println!("listening on http://{}", server.local_addr().unwrap());
//! server.run().unwrap(); // serves until POST /shutdown
//! ```
//!
//! # Protocol reference
//!
//! Every request and response body is JSON (`Content-Type:
//! application/json`); errors are `{"error": "<message>"}` with a 4xx/5xx
//! status. Boolean options can be sent either as body fields or as query
//! parameters (`?factors=1`).
//!
//! ## `POST /sessions` — create a session
//!
//! ```json
//! {
//!   "name": "train-7",                 // optional; auto-generated "sN"
//!   "dataset": {                        // optional; default two-moons
//!     "generator": "two-moons",         // or abalone|borg|mnist|salinas|
//!                                       //    lightfield|tiny-images
//!     "n": 2000, "seed": 7,
//!     "noise": 0.05,                    // two-moons only
//!     "dim": 0                          // 0 = generator default
//!   },
//!   // …or inline data: "dataset": {"points": [[x0,…], [x1,…], …]}
//!   // …or a file:      "dataset": {"file": "sets/train.csv"}
//!   //    (CSV or oasis-matrix binary; the path resolves under the
//!   //     server's --fs-root and may not escape it)
//!   "kernel": {                         // optional; default gaussian
//!     "type": "gaussian",               // or linear|laplacian|polynomial
//!     "sigma": 0.5,                     // explicit σ…
//!     "sigma_fraction": 0.05            // …or fraction of max distance
//!   },
//!   "method": "oasis",                  // or sis|farahat|icd|
//!                                       //    adaptive-random|oasis-p
//!   "max_cols": 450, "init_cols": 10,   // sampler parameters
//!   "tol": 1e-12, "seed": 7,
//!   "batch": 10,                        // adaptive-random only
//!   "workers": 4,                       // oasis-p only
//!   "merge_batch": 1,                   // oasis-p only (1..=64): SQUEAK
//!                                       //   merge width — candidates
//!                                       //   admitted per argmax round.
//!                                       //   1 (default) is the exact
//!                                       //   sequential protocol; >1
//!                                       //   trades selection order for
//!                                       //   fewer gather rounds. Session
//!                                       //   stats gain a "workers" array
//!                                       //   of per-worker counters.
//!   "warm_start": "models/seed.oasis",  // optional (oasis|sis methods):
//!                                       //   resume selection from a
//!                                       //   stored artifact's Λ — the
//!                                       //   session starts at the
//!                                       //   artifact's k and extends
//!                                       //   it. The run's dataset/
//!                                       //   kernel must match the
//!                                       //   artifact's (checked). For
//!                                       //   a *bit-exact* resume, also
//!                                       //   pass the init_cols the
//!                                       //   recording run used (not
//!                                       //   stored in the artifact; a
//!                                       //   different split is still a
//!                                       //   valid resume, just not
//!                                       //   bitwise). Path resolves
//!                                       //   under --fs-root.
//!   "shard_reads": false                // optional (oasis-p + a binary
//!                                       //   dataset file): each worker
//!                                       //   reads only its own byte
//!                                       //   range of the file; the
//!                                       //   server holds no full
//!                                       //   dataset (queries and saves
//!                                       //   use the selected points
//!                                       //   mirrored from the leader).
//!                                       //   Needs a kernel that
//!                                       //   resolves without data
//!                                       //   (e.g. explicit sigma).
//! }
//! ```
//!
//! The create payload *is* an [`engine::RunSpec`](crate::engine::RunSpec)
//! in JSON: the parser ([`protocol`]) decodes into the same spec types
//! the CLI builds from flags, and the registry resolves them through the
//! same [`engine::SessionBuilder`](crate::engine::SessionBuilder) — which
//! is why a server-hosted run is bit-identical to the equivalent CLI run.
//!
//! → `{"name", "method", "n", "dim", "k", "error_estimate"}`. `409` if the
//! name exists. Note `farahat` and `adaptive-random` materialize the full
//! n×n residual at creation — use them for explicit-scale datasets only.
//! Serving-sanity caps apply (see [`protocol`]'s `MAX_*` constants):
//! dataset size, dimensionality, worker count, n×n-residual methods, and
//! n×max_cols session state are all bounded so one request cannot abort
//! the server with an oversized allocation.
//!
//! ## `POST /sessions/{name}/step` — grow the approximation
//!
//! ```json
//! {
//!   "steps": 25,            // max selections this batch (default 1, or
//!                           // unbounded if "budget" is given)
//!   "target_err": 1e-3,     // optional any-of stopping criteria,
//!   "deadline_ms": 500,     // evaluated before every step in this
//!   "score_below": 1e-9,    // order (first match names the stop)
//!   "budget": 450,          // total-k cap (counts seed columns)
//!   "background": false     // true → 202 now, work proceeds on the
//!                           // session's actor thread
//! }
//! ```
//!
//! → `{"name", "k", "stepped", "error_estimate", "secs", "stop"?}` where
//! `stop` ∈ `budget|score-tol|error-target|deadline|exhausted` when the
//! batch ended early. Steps on one session serialize in arrival order;
//! different sessions step in parallel.
//!
//! ## `GET /sessions/{name}/snapshot` — current factors, mid-run
//!
//! Options: `factors` (include `"c"`/`"winv"` as
//! `{"rows","cols","data"}`), `cached` (reuse the last snapshot instead
//! of gathering a fresh one). → `{"name", "n", "k", "indices",
//! "error_estimate", "selection_secs", "c"?, "winv"?}`. The run can keep
//! stepping afterwards — snapshots are consistent prefixes.
//!
//! ## `POST /sessions/{name}/query` — out-of-sample extension
//!
//! ```json
//! {"points": [[x,…], …], "targets": [0, 17], "refresh": false}
//! ```
//!
//! For each query point z the server computes `b = k(z, x_Λ)` against the
//! live snapshot's selected points and returns the Nyström extension
//! weights `w = W⁻¹ b` (length k), plus `ĝ(z, i) = wᵀC(i,:)` for each
//! requested target row. Only the k selected points are touched — O(k²)
//! per point. `refresh` forces a fresh snapshot first; otherwise the
//! cached one is reused across queries.
//!
//! → `{"name", "snapshot_k", "results": [{"weights": […], "kernel": […]?}]}`
//!
//! ## `POST /sessions/{name}/task` — fit + run a downstream task
//!
//! ```json
//! {
//!   "task": "krr",              // krr|kpca|cluster (default krr)
//!   "ridge": 1e-3,              // krr regularization λ > 0
//!   "components": 2,            // kpca/cluster embedding dims
//!   "clusters": 2,              // cluster count (cluster task)
//!   "seed": 7,                  // cluster k-means seeding
//!   "labels": [0, 1, 0, …],     // krr training labels, inline — a flat
//!                               //    array, or per-point rows
//!                               //    [[y0a,y0b], …] for multi-output
//!                               //    krr (m outputs share one
//!                               //    factorization)
//!   "labels_file": "y.csv",     // …or dataset file column(s) (resolves
//!   "label_col": 0,             //    under --fs-root; default col 0)
//!   "label_cols": [0, 2],       //    …or several columns — an index
//!                               //    array or a range string "0,2-4"
//!                               //    (mutually exclusive with
//!                               //    label_col) → multi-output krr
//!   "predict": [[x,…], …],      // points to predict for (optional)
//!   "f32": false,               // true → serve predictions through the
//!                               //    f32 kernel-block path (krr only):
//!                               //    ~half the block memory traffic,
//!                               //    single-precision results (~1e-6
//!                               //    relative — see
//!                               //    tasks::FittedTask::predict_f32)
//!   "refresh": false            // fresh snapshot before fitting
//! }
//! ```
//!
//! Fits the task on the session's current snapshot — KRR dual weights,
//! kernel-PCA eigenpairs, or spectral k-means — in O(nk²), never
//! materializing the n×n matrix, and predicts for the given points by
//! evaluating the kernel against the k selected points only. A B-point
//! `predict` array is served as **one** B×k kernel block evaluation
//! plus one blocked matrix product against the dual weights
//! ([`tasks::landmark_block`](crate::tasks::landmark_block)) — batching
//! B points into one request costs far less than B single-point
//! requests, and the results are bit-identical to the single-point path
//! (f64). Multi-output krr responds with one row of m values per
//! predict point and reports `"outputs": m` in the fit summary.
//! Identical
//! consecutive requests reuse the cached fitted model (`"model":
//! "cached"`; see the `tasks_fitted`/`task_cache_hits`/
//! `task_predictions` counters in `/metrics`), and a krr request
//! **without** labels reuses the session's cached fitted model when it
//! is a krr model — fit once with labels, then serve predict-only
//! traffic without re-shipping or re-reading the label set. (The cache
//! holds one model per session: fitting a different task in between
//! evicts it, and the next label-free krr request is a 400 until
//! labels are shipped again.)
//!
//! → the fit summary (`{"task", "k", …}` — e.g. `ridge`+`train_rmse`
//! for krr, `eigenvalues` for kpca, `clusters` for cluster) plus
//! `{"name", "model": "fitted"|"cached", "predictions"?}` where
//! `predictions` is one value (krr), embedding vector (kpca), or
//! cluster label (cluster) per point — rendered by the same serializer
//! as `oasis task --json`, so front-end answers are byte-comparable.
//!
//! ## `POST /artifacts/{name}/task` — downstream task, dataset-free
//!
//! Same payload and response as the session task endpoint, but fit on a
//! loaded artifact's stored factors and answered from its k stored
//! selected points — no dataset, no oracle (`refresh` is ignored). A
//! `krr` request **without** labels reuses the fitted model persisted
//! in the artifact's task section, if any (`"model": "stored"`) — the
//! `sample → save → fit → predict` pipeline's serving end.
//!
//! ## `POST /sessions/{name}/save` — persist the approximation
//!
//! ```json
//! {"path": "models/train-7.oasis", "f32": false}
//! ```
//!
//! Takes a fresh snapshot of the (still-running) session and writes it
//! as a versioned artifact file — indices, `C`, `W⁻¹`, the k selected
//! points, resolved kernel parameters, dataset provenance, and the
//! current error estimate, checksummed (format documented in
//! [`crate::nystrom::store`]). `"f32": true` stores the `C`/`W⁻¹`
//! payload in f32 (half the bytes; lossy — reloaded factors, queries,
//! and task fits then carry f32 precision, while the selected points
//! stay f64-exact). The path resolves under `--fs-root`
//! (relative, no `..`). → `{"name", "path", "n", "k", "bytes"}`. The
//! session keeps running; save again later for a bigger artifact.
//!
//! ## `POST /artifacts/load` — host a stored artifact
//!
//! ```json
//! {"path": "models/train-7.oasis", "name": "prod"}   // name optional ("aN")
//! ```
//!
//! Loads and verifies an artifact file and hosts it as a **query-only**
//! read replica: no actor thread, immutable, any number of concurrent
//! queries. → the artifact status object (`{"name", "n", "k", "dim",
//! "kernel", "method", "source", "error_estimate", …}`). `409` if the
//! name exists; `400` for corrupt/truncated/wrong-version files.
//!
//! ## `POST /artifacts/{name}/query` — query without the original data
//!
//! Same payload and response shape as the session query (`points` +
//! optional `targets`), but answered entirely from the stored factors
//! and the k stored selected points — the original dataset and kernel
//! oracle are not needed (`refresh` is meaningless here and ignored).
//!
//! ## Other endpoints
//!
//! | endpoint | effect |
//! |---|---|
//! | `GET /sessions` | `{"sessions": [status…]}` (name-sorted) |
//! | `GET /sessions/{name}` | status: `k`, `busy`, `steps_done`, `error_estimate`, `best_score`, `step_latency`, `stop`?, `failed`? |
//! | `GET /sessions/{name}/trajectory` | convergence telemetry: `{"name", "count", "dropped", "capacity", "points"}` — one `{step, k, error_estimate, best_score, step_us}` per adaptive selection, oldest first, bounded ring |
//! | `POST /sessions/{name}/finish` (or `DELETE /sessions/{name}`) | final factors + eviction; options: `factors` |
//! | `GET /artifacts` | `{"artifacts": [status…]}` (name-sorted) |
//! | `GET /artifacts/{name}` | one artifact's status (incl. `queries` served) |
//! | `DELETE /artifacts/{name}` | unload a hosted artifact |
//! | `GET /metrics` | `{"uptime_secs", "start_time_unix_secs", "version", "server": counters, "predict": histograms, "sessions": […], "trajectory": {name: summary}, "artifacts": […]}` |
//! | `POST /debug/trace` | `{"enable": bool, "capacity": n}` — toggle (and size) the live span recorder at runtime |
//! | `GET /debug/trace` | drain buffered spans as Chrome `trace_event` JSON (`?format=jsonl` for line-delimited); destructive read |
//! | `GET /healthz` | `{"ok": true, "uptime_secs", "start_time_unix_secs", "version"}` |
//! | `POST /shutdown` | stop accepting, drain in-flight requests, tear down all sessions |
//!
//! ## Serving operations
//!
//! Connections are handled by a **fixed worker pool** fed from a
//! bounded accept queue (`oasis serve --threads N --queue Q`; threads
//! default to the machine's available parallelism). Connections beyond
//! `threads + queue` receive a one-shot `503` — backpressure is
//! explicit, not an unbounded thread spawn. Connections are HTTP/1.1
//! **keep-alive** by default: send requests back to back on one socket
//! (`Connection: close` or a ~30 s idle timeout ends one).
//!
//! Optional **rate limits** (`--max-rps`, `--max-rps-per-ip`; fixed
//! 1-second windows) answer over-cap requests with `429`; `/healthz`
//! and `/shutdown` are exempt. Shed work shows up in the
//! `rate_limited` / `rejected_overload` counters.
//!
//! **Shutdown is graceful**: `POST /shutdown` stops the accept loop,
//! waits up to `--drain-ms` (default 5000) for in-flight requests to
//! finish writing their responses, then tears down the session actors.
//!
//! `oasis bench-serve` drives a live server with N concurrent
//! keep-alive connections and reports p50/p99 latency and requests/sec
//! for single-point vs. batched predict (the `serve` section of
//! `BENCH_ci.json` in CI).
//!
//! ## Observability
//!
//! Three pillars, one per subsystem of [`crate::obs`]:
//!
//! 1. **Structured logging** ([`crate::obs::log`]). Every dispatched
//!    request emits one leveled log line (text or JSON lines under
//!    `oasis serve --log-json`; threshold via `--log-level`) carrying
//!    `request_id`, `seq`, `method`, `path`, `status`, and `ms`. The
//!    request id is the client's `X-Request-Id` header when it supplies
//!    a plausible one (non-empty, ≤128 printable-ASCII chars), otherwise
//!    generated, and is **echoed back** as an `X-Request-Id` response
//!    header on every response (429s included) — so a client, the
//!    server log, and the trace can be joined on one key.
//! 2. **Latency histograms + Prometheus** (details below): request
//!    durations, step latencies, and — new — per-session convergence
//!    gauges (`oasis_session_error_estimate`,
//!    `oasis_session_best_score`) plus a `"trajectory"` summary section
//!    in the JSON report; the full per-step series lives at
//!    `GET /sessions/{name}/trajectory`.
//! 3. **Live tracing** ([`crate::obs::trace`]). `POST /debug/trace`
//!    turns the process-wide span recorder on (or off) at runtime with a
//!    bounded ring capacity; `GET /debug/trace` drains whatever buffered
//!    since the last drain as a Chrome `trace_event` document —
//!    `about:tracing` / Perfetto-loadable — or JSONL. Each routed
//!    request contributes an `http_request` span and a `request_id`
//!    counter event whose value is the log line's `seq`, which is how a
//!    span is tied back to a specific request id. No filesystem paths
//!    are involved, so the endpoint is usable on a locked-down
//!    `--fs-root`.
//!
//! Every latency the server reports is a log₂-bucketed histogram
//! ([`crate::obs::hist`]) carrying `count`/`mean_ms`/`last_ms`/`max_ms`
//! **plus** `p50_ms`/`p90_ms`/`p99_ms` quantile estimates: the
//! per-session `step_latency` in the status/metrics JSON, and
//! per-endpoint request durations recorded around every dispatched
//! request (labels are normalized — `POST /sessions/train-7/step`
//! records under `POST /sessions/{name}/step`, so the label set stays
//! bounded).
//!
//! `GET /metrics` additionally serves **Prometheus text exposition**
//! (version 0.0.4) when asked — via the query parameter
//! `?format=prometheus`, or an `Accept` header mentioning `text/plain`
//! or `openmetrics`:
//!
//! ```bash
//! curl localhost:7437/metrics?format=prometheus
//! curl -H 'Accept: text/plain' localhost:7437/metrics
//! ```
//!
//! The page carries `oasis_build_info{version=…}`,
//! `oasis_start_time_seconds`, `oasis_uptime_seconds`, every JSON
//! counter as an `oasis_*_total` counter, request durations as
//! cumulative `oasis_http_request_duration_seconds_bucket{endpoint=…}`
//! histogram series (`_sum`/`_count` included), per-session step
//! histograms (`oasis_session_steps_total`,
//! `oasis_session_step_duration_seconds`, `oasis_session_columns`,
//! `oasis_session_error_estimate`, `oasis_session_best_score`), and —
//! for live distributed
//! (oasis-p) sessions — per-worker gauges scraped mid-run
//! (`oasis_worker_heartbeat_age_seconds`, `oasis_worker_reshards_total`,
//! `oasis_worker_wire_bytes_total`, …) labeled
//! `{session="…", worker="…"}`. `oasis promcheck --port P` scrapes and
//! validates a page end to end ([`crate::obs::prom::validate`] — the CI
//! smoke jobs run exactly that). JSON remains the default rendering and
//! is unchanged apart from the added fields above.
//!
//! ## Consistency guarantees
//!
//! A session's selection sequence is bit-identical to the equivalent
//! offline run (`session(...)` + `run_to_completion`) with the same
//! dataset/kernel/method parameters: the server adds no randomness and
//! every snapshot is a consistent k-column prefix of that sequence —
//! which is what the socket-level acceptance test in
//! `rust/tests/server.rs` asserts.

pub mod artifacts;
pub mod handlers;
pub mod http;
pub mod metrics;
pub mod protocol;
pub mod registry;

pub use artifacts::ArtifactRegistry;
pub use http::{Request, Response};
pub use metrics::ServerMetrics;
pub use registry::{Registry, SessionHandle};

use crate::Result;
use std::collections::HashMap;
use std::io::BufReader;
use std::net::{IpAddr, SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Operator-side server configuration (CLI flags, not request payloads).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Root directory under which every client-supplied path (dataset
    /// `{"file": …}`, artifact save/load) resolves; clients cannot reach
    /// outside it (see [`protocol::resolve_fs_path`]).
    pub fs_root: PathBuf,
    /// Connection worker threads (`--threads`; 0 = available
    /// parallelism). The pool is fixed-size: a malicious burst of
    /// connections occupies the bounded accept queue, not one OS thread
    /// each.
    pub threads: usize,
    /// Accepted-connection queue depth (`--queue`). When every worker is
    /// busy and the queue is full, new connections get a one-shot 503
    /// instead of stalling the accept loop.
    pub queue: usize,
    /// Global request-rate cap per second (`--max-rps`; 0 = unlimited).
    /// Over-cap requests are answered 429; `/healthz` and `/shutdown`
    /// are exempt so probes and operators are never locked out.
    pub max_rps: u64,
    /// Per-client-IP request-rate cap per second (`--max-rps-per-ip`;
    /// 0 = unlimited), same 429 semantics.
    pub max_rps_per_ip: u64,
    /// How long shutdown waits for in-flight requests to finish before
    /// tearing sessions down (`--drain-ms`).
    pub drain: Duration,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            fs_root: PathBuf::from("."),
            threads: 0,
            queue: 128,
            max_rps: 0,
            max_rps_per_ip: 0,
            drain: Duration::from_secs(5),
        }
    }
}

impl ServerConfig {
    /// The worker count actually spawned: `threads`, or the machine's
    /// available parallelism when 0 (min 2 so one slow request can never
    /// starve `/healthz`).
    pub fn resolved_threads(&self) -> usize {
        let n = if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            self.threads
        };
        n.max(2)
    }
}

/// One fixed one-second rate window: request counts since
/// `started`, globally and per peer IP. Fixed (not sliding) windows
/// admit at most 2× the cap across a window boundary — acceptable for
/// overload shedding, and O(1) per request with no timestamp ring.
#[derive(Debug)]
struct RateWindow {
    started: Instant,
    global: u64,
    per_ip: HashMap<IpAddr, u64>,
}

/// Shared server state: the session registry, hosted artifacts,
/// counters, and the stop flag.
pub struct ServerState {
    pub registry: Registry,
    pub artifacts: ArtifactRegistry,
    pub config: ServerConfig,
    pub metrics: ServerMetrics,
    pub started: Instant,
    /// Wall-clock start time (Unix seconds), for
    /// `oasis_start_time_seconds` and `/healthz` — the monotonic
    /// [`started`](ServerState::started) clock drives `uptime_secs`.
    pub start_unix_secs: f64,
    stop: AtomicBool,
    /// Requests currently inside [`handlers::route`] — the graceful
    /// shutdown drain waits for this to reach zero (or the
    /// [`drain`](ServerConfig::drain) deadline) before tearing sessions
    /// down.
    in_flight: AtomicU64,
    rate: Mutex<RateWindow>,
}

impl ServerState {
    fn new(config: ServerConfig) -> ServerState {
        let start_unix_secs = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0);
        ServerState {
            registry: Registry::new(),
            artifacts: ArtifactRegistry::new(),
            config,
            metrics: ServerMetrics::default(),
            started: Instant::now(),
            start_unix_secs,
            stop: AtomicBool::new(false),
            in_flight: AtomicU64::new(0),
            rate: Mutex::new(RateWindow {
                started: Instant::now(),
                global: 0,
                per_ip: HashMap::new(),
            }),
        }
    }

    pub fn stopping(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    /// Ask the accept loop to exit (what `POST /shutdown` does).
    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    /// Requests currently being routed (see the shutdown drain).
    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Admit one request from `ip` under the configured rate caps; a
    /// `false` turns into a 429. Counting happens even for requests that
    /// end up rejected — a client hammering past the cap stays rejected
    /// rather than sneaking through once the admitted count stalls.
    fn admit(&self, ip: IpAddr) -> bool {
        if self.config.max_rps == 0 && self.config.max_rps_per_ip == 0 {
            return true;
        }
        let mut w = self.rate.lock().unwrap_or_else(|p| p.into_inner());
        if w.started.elapsed() >= Duration::from_secs(1) {
            w.started = Instant::now();
            w.global = 0;
            w.per_ip.clear();
        }
        w.global += 1;
        let per = w.per_ip.entry(ip).or_insert(0);
        *per += 1;
        (self.config.max_rps == 0 || w.global <= self.config.max_rps)
            && (self.config.max_rps_per_ip == 0
                || *per <= self.config.max_rps_per_ip)
    }
}

/// The `oasis serve` server: a bound listener plus shared state.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
}

impl Server {
    /// Bind (e.g. `"127.0.0.1:7437"`, or port `0` for an ephemeral port —
    /// read it back with [`local_addr`](Server::local_addr)) with the
    /// default configuration (`fs_root` = current directory).
    pub fn bind(addr: &str) -> Result<Server> {
        Server::bind_with(addr, ServerConfig::default())
    }

    /// Bind with an explicit [`ServerConfig`].
    pub fn bind_with(addr: &str, config: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(addr)?;
        // non-blocking accept so the stop flag is polled between peers
        listener.set_nonblocking(true)?;
        Ok(Server { listener, state: Arc::new(ServerState::new(config)) })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// A handle to the shared state (for in-process callers/tests: request
    /// a stop, inspect metrics, drive the registry directly).
    pub fn state(&self) -> Arc<ServerState> {
        self.state.clone()
    }

    /// Serve until [`ServerState::request_stop`] (usually `POST
    /// /shutdown`), then drain in-flight requests (up to
    /// [`ServerConfig::drain`]) and tear down every session.
    ///
    /// Connections are handled by a fixed pool of
    /// [`resolved_threads`](ServerConfig::resolved_threads) workers fed
    /// from a bounded accept queue — a connection burst beyond
    /// `threads + queue` is shed with one-shot 503s instead of spawning
    /// unbounded OS threads. Each connection is kept alive until the
    /// peer closes, sends `Connection: close`, or idles past the read
    /// timeout.
    pub fn run(self) -> Result<()> {
        let threads = self.state.config.resolved_threads();
        let queue = self.state.config.queue.max(1);
        let (tx, rx) = std::sync::mpsc::sync_channel::<TcpStream>(queue);
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..threads {
            let rx = rx.clone();
            let state = self.state.clone();
            std::thread::Builder::new()
                .name(format!("serve-worker-{i}"))
                .spawn(move || loop {
                    // holding the lock across recv() is the standard
                    // shared-receiver pool shape: one idle worker waits,
                    // the rest contend only at dequeue time
                    let next = {
                        let guard =
                            rx.lock().unwrap_or_else(|p| p.into_inner());
                        guard.recv()
                    };
                    match next {
                        Ok(stream) => handle_conn(stream, state.clone()),
                        Err(_) => return, // accept loop dropped the sender
                    }
                })?;
        }
        let mut consecutive_errors = 0u32;
        loop {
            // checked every iteration — a stream of incoming connections
            // must not postpone shutdown past the current accept
            if self.state.stopping() {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    consecutive_errors = 0;
                    ServerMetrics::inc(&self.state.metrics.connections);
                    // accepted sockets must block; the listener's
                    // non-blocking flag is not inherited on all platforms
                    let _ = stream.set_nonblocking(false);
                    match tx.try_send(stream) {
                        Ok(()) => {}
                        Err(std::sync::mpsc::TrySendError::Full(stream)) => {
                            overloaded(&self.state, stream);
                        }
                        Err(std::sync::mpsc::TrySendError::Disconnected(_)) => {
                            break;
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    consecutive_errors = 0;
                    if self.state.stopping() {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    // transient accept errors (a peer that RSTs before
                    // accept → ECONNABORTED, fd exhaustion → EMFILE) must
                    // not take down every hosted session; back off and
                    // retry, giving up only on persistent failure
                    if self.state.stopping() {
                        break;
                    }
                    consecutive_errors += 1;
                    if consecutive_errors >= 100 {
                        self.drain_and_shutdown(tx);
                        return Err(e.into());
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
        self.drain_and_shutdown(tx);
        Ok(())
    }

    /// Graceful shutdown: stop feeding workers, wait for in-flight
    /// requests to finish (bounded by the drain deadline — a wedged
    /// handler must not hold shutdown hostage), then tear down the
    /// session actors. Idle keep-alive connections are not waited on;
    /// their workers notice the stop flag at the next request or read
    /// timeout.
    fn drain_and_shutdown(&self, tx: std::sync::mpsc::SyncSender<TcpStream>) {
        drop(tx);
        let deadline = Instant::now() + self.state.config.drain;
        while self.state.in_flight() > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        self.state.registry.shutdown();
    }
}

/// Monotonic request sequence number — the numeric correlation key a
/// request's structured log line shares with its `request_id` trace
/// event (span names are static strings, so the string id itself cannot
/// ride in the trace).
static REQUEST_SEQ: AtomicU64 = AtomicU64::new(0);

/// Per-process time base mixed into generated `X-Request-Id` values so
/// ids from successive server processes don't collide.
static REQUEST_ID_BASE: std::sync::OnceLock<u64> = std::sync::OnceLock::new();

fn request_id_base() -> u64 {
    *REQUEST_ID_BASE.get_or_init(|| {
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0)
    })
}

/// The id attached to (and echoed from) one request: the client's
/// `X-Request-Id` when it supplies a plausible one — non-empty, at most
/// 128 chars, printable ASCII (no header-splitting or log-forging
/// bytes) — otherwise a generated `{base:x}-{seq:x}`, unique for the
/// life of the process.
fn request_id(req: &Request, seq: u64) -> String {
    match req.headers.get("x-request-id") {
        Some(v)
            if !v.is_empty()
                && v.len() <= 128
                && v.bytes().all(|b| b.is_ascii_graphic()) =>
        {
            v.clone()
        }
        _ => format!("{:x}-{seq:x}", request_id_base()),
    }
}

/// Shed one connection the accept queue cannot hold: a one-shot 503 and
/// close, so the peer sees an explicit overload signal instead of a
/// connection that hangs until some worker frees up.
fn overloaded(state: &Arc<ServerState>, mut stream: TcpStream) {
    ServerMetrics::inc(&state.metrics.rejected_overload);
    let resp = Response::json(
        503,
        crate::util::json::Json::obj(vec![(
            "error",
            crate::util::json::Json::Str(
                "server overloaded: accept queue full — retry".into(),
            ),
        )]),
    );
    let _ = resp.write_to(&mut stream, true);
}

/// One connection: read requests until EOF/close, dispatch each.
fn handle_conn(stream: TcpStream, state: Arc<ServerState>) {
    // bound idle keep-alive connections
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let peer_ip = stream
        .peer_addr()
        .map(|a| a.ip())
        .unwrap_or(IpAddr::V4(std::net::Ipv4Addr::UNSPECIFIED));
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader_stream);
    let mut writer = stream;
    loop {
        match http::read_request(&mut reader, &mut writer) {
            Ok(Some(req)) => {
                // rate caps shed real work, never health probes or the
                // operator's shutdown path
                let exempt =
                    matches!(req.path.as_str(), "/healthz" | "/shutdown");
                let rate_limited = !exempt && !state.admit(peer_ip);
                let seq = REQUEST_SEQ.fetch_add(1, Ordering::Relaxed) + 1;
                let rid = request_id(&req, seq);
                let resp = if rate_limited {
                    ServerMetrics::inc(&state.metrics.rate_limited);
                    crate::obs::log::warn(
                        "server",
                        "rate limited",
                        &[
                            ("request_id", rid.clone()),
                            ("seq", seq.to_string()),
                            ("method", req.method.clone()),
                            ("path", req.path.clone()),
                        ],
                    );
                    Response::json(
                        429,
                        crate::util::json::Json::obj(vec![(
                            "error",
                            crate::util::json::Json::Str(
                                "rate limit exceeded — retry later".into(),
                            ),
                        )]),
                    )
                } else {
                    let t0 = Instant::now();
                    state.in_flight.fetch_add(1, Ordering::SeqCst);
                    let resp = {
                        // the request-duration span plus a counter event
                        // carrying this request's seq — the join key back
                        // to the log line's request_id
                        let _span =
                            crate::obs::trace::span("http_request", "server");
                        crate::obs::trace::event(
                            "request_id",
                            "server",
                            seq as f64,
                        );
                        handlers::route(&state, &req)
                    };
                    let elapsed = t0.elapsed().as_secs_f64();
                    state.metrics.observe_request(
                        &handlers::endpoint_label(&req),
                        elapsed,
                    );
                    crate::obs::log::info(
                        "server",
                        "request",
                        &[
                            ("request_id", rid.clone()),
                            ("seq", seq.to_string()),
                            ("method", req.method.clone()),
                            ("path", req.path.clone()),
                            ("status", resp.status.to_string()),
                            ("ms", format!("{:.3}", elapsed * 1e3)),
                        ],
                    );
                    resp
                };
                let resp = resp.with_header("X-Request-Id", rid);
                // check the stop flag *after* routing so /shutdown closes
                // its own connection
                let close = req.wants_close() || state.stopping();
                let write_res = resp.write_to(&mut writer, close);
                if !rate_limited {
                    // decremented only after the response is on the wire:
                    // the shutdown drain then guarantees an in-flight
                    // request's bytes were written, not just computed
                    state.in_flight.fetch_sub(1, Ordering::SeqCst);
                }
                if write_res.is_err() || close {
                    return;
                }
            }
            Ok(None) => return, // peer closed between requests
            Err(e) => {
                // an idle keep-alive connection hitting the read timeout
                // is closed silently — writing an unsolicited 400 here
                // could be mistaken for the response to the client's next
                // pipelined request
                let idle = matches!(
                    e.kind(),
                    std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
                );
                if !idle {
                    let resp = Response::json(
                        400,
                        crate::util::json::Json::obj(vec![(
                            "error",
                            crate::util::json::Json::Str(
                                "malformed HTTP request".into(),
                            ),
                        )]),
                    );
                    let _ = resp.write_to(&mut writer, true);
                }
                return;
            }
        }
    }
}
