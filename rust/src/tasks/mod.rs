//! Downstream tasks on top of a Nyström approximation — the consumer
//! layer the paper motivates in its opening line: kernel matrices are
//! "essential for many state-of-the-art approaches to classification,
//! clustering, and dimensionality reduction". This module runs exactly
//! those three workloads on an approximation **without ever
//! materializing the full kernel matrix**:
//!
//! * [`krr`] — Nyström kernel ridge regression: dual weights fit from
//!   the rank-k factors in O(nk²), out-of-sample prediction through the
//!   extension machinery (`f(z) = b(z)ᵀ β`, touching only the k selected
//!   points).
//! * [`kpca`] — kernel PCA / spectral embedding: top-d eigenpairs of G̃
//!   via [`nystrom_eig`](crate::nystrom::nystrom_eig), projecting both
//!   in-sample and out-of-sample points.
//! * [`cluster`] — spectral k-means on the embedding, reusing the
//!   k-means machinery from [`crate::sampling::kmeans`].
//!
//! Every fit consumes only `(C, W⁻¹, indices)` — a live session
//! snapshot, a finished run, or a loaded [`StoredArtifact`] all work,
//! and the artifact case is **dataset-free**: prediction evaluates the
//! kernel against the k stored selected points only, exactly like the
//! extension queries. Fits are deterministic functions of the factor
//! bits, so the CLI (`oasis task`), a live server session
//! (`POST /sessions/{name}/task`), and a loaded artifact
//! (`POST /artifacts/{name}/task`) produce bit-identical models and
//! predictions from the same approximation.
//!
//! Fitted models persist: the artifact store appends a versioned `task`
//! section ([`crate::nystrom::store`]), so a `sample → save → fit →
//! predict` pipeline can hand its model to a process that has neither
//! the dataset nor the labels (`examples/krr_pipeline.rs`).
//!
//! [`StoredArtifact`]: crate::nystrom::StoredArtifact

pub mod cluster;
pub mod kpca;
pub mod krr;

pub use cluster::ClusterModel;
pub use kpca::KpcaModel;
pub use krr::KrrModel;

use crate::data::Dataset;
use crate::kernels::Kernel;
use crate::linalg::Mat;
use crate::nystrom::NystromApprox;
use crate::util::json::Json;
use crate::Result;
use crate::{anyhow, bail};

/// Which downstream task to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// Kernel ridge regression (needs labels).
    Krr,
    /// Kernel PCA / spectral embedding.
    Kpca,
    /// Spectral k-means clustering on the embedding.
    Cluster,
}

impl TaskKind {
    pub fn parse(s: &str) -> Result<TaskKind> {
        Ok(match s {
            "krr" => TaskKind::Krr,
            "kpca" => TaskKind::Kpca,
            "cluster" => TaskKind::Cluster,
            other => bail!("unknown task '{other}' (expected krr|kpca|cluster)"),
        })
    }

    /// The canonical spelling [`parse`](TaskKind::parse) accepts.
    pub fn as_str(self) -> &'static str {
        match self {
            TaskKind::Krr => "krr",
            TaskKind::Kpca => "kpca",
            TaskKind::Cluster => "cluster",
        }
    }

    /// The shared CLI/server default embedding dimensionality: one
    /// dimension per cluster for the cluster task, 2 otherwise. (In one
    /// place so the front ends cannot drift.)
    pub fn default_components(self, clusters: usize) -> usize {
        match self {
            TaskKind::Cluster => clusters,
            TaskKind::Krr | TaskKind::Kpca => 2,
        }
    }
}

/// A fully resolved task configuration — labels already loaded, every
/// parameter validated. The engine resolves a
/// [`TaskSpec`](crate::engine::TaskSpec) (which still holds file paths)
/// into this; tests and the library construct it directly.
#[derive(Clone, Debug)]
pub struct TaskConfig {
    pub kind: TaskKind,
    /// Ridge λ (KRR; must be > 0 — λ = 0 would invert a singular G̃).
    pub ridge: f64,
    /// Embedding dimensions d (KPCA, and the spectral-cluster embedding).
    pub components: usize,
    /// Cluster count (cluster task).
    pub clusters: usize,
    /// K-means seeding RNG (cluster task).
    pub seed: u64,
    /// Training labels (KRR only), output-major: one column per output,
    /// each holding one label per data point. Single-output KRR is the
    /// one-column case.
    pub labels: Option<Vec<Vec<f64>>>,
}

impl TaskConfig {
    /// A config with the CLI/server defaults for `kind`; set the fields
    /// the task reads before fitting.
    pub fn new(kind: TaskKind) -> TaskConfig {
        TaskConfig {
            kind,
            ridge: 1e-3,
            components: 2,
            clusters: 2,
            seed: 7,
            labels: None,
        }
    }

    /// Validate the parameters the task will read. (Label length is
    /// checked against n at fit time.)
    pub fn validate(&self) -> Result<()> {
        match self.kind {
            TaskKind::Krr => {
                if !(self.ridge.is_finite() && self.ridge > 0.0) {
                    bail!("krr ridge must be a finite number > 0");
                }
                match &self.labels {
                    None => {
                        bail!("krr needs training labels (one per data point)")
                    }
                    Some(cols) => {
                        if cols.is_empty() {
                            bail!("krr needs at least one label column");
                        }
                        let n = cols[0].len();
                        if let Some(j) =
                            cols.iter().position(|c| c.len() != n)
                        {
                            bail!(
                                "krr label column {j} has {} labels but \
                                 column 0 has {n}",
                                cols[j].len()
                            );
                        }
                    }
                }
            }
            TaskKind::Kpca => {
                if self.components == 0 {
                    bail!("kpca needs components ≥ 1");
                }
            }
            TaskKind::Cluster => {
                if self.clusters < 2 {
                    bail!("cluster needs clusters ≥ 2");
                }
                if self.components == 0 {
                    bail!("cluster needs components ≥ 1");
                }
            }
        }
        Ok(())
    }
}

/// A fitted downstream model. Everything a model holds lives in the
/// k-dimensional landmark space (plus d-dimensional embedding state), so
/// prediction needs only the kernel and the k selected points — the same
/// dataset-free contract as the artifact extension queries.
#[derive(Clone, Debug)]
pub enum FittedTask {
    Krr(KrrModel),
    Kpca(KpcaModel),
    Cluster(ClusterModel),
}

/// A fit plus its in-sample by-products (reported once, not stored in
/// the model: they are O(n)).
#[derive(Clone, Debug)]
pub struct TaskFit {
    pub model: FittedTask,
    /// In-sample cluster labels (cluster task only).
    pub cluster_labels: Option<Vec<usize>>,
}

/// Per-point predictions, shaped by the task.
#[derive(Clone, Debug)]
pub enum TaskPrediction {
    /// Single-output KRR: one regression value per query point.
    Values(Vec<f64>),
    /// Multi-output KRR: one m-vector of regression values per query
    /// point.
    Matrix(Vec<Vec<f64>>),
    /// KPCA: one d-vector of embedding coordinates per query point.
    Embeddings(Vec<Vec<f64>>),
    /// Cluster: one label per query point, plus its embedding.
    Labels { labels: Vec<usize>, embeddings: Vec<Vec<f64>> },
}

impl TaskPrediction {
    /// The `"predictions"` JSON value (shared by the CLI and the server,
    /// so their rendered predictions are byte-identical).
    pub fn to_json(&self) -> Json {
        match self {
            TaskPrediction::Values(v) => {
                Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
            }
            TaskPrediction::Matrix(rows) | TaskPrediction::Embeddings(rows) => {
                Json::Arr(
                    rows.iter()
                        .map(|r| {
                            Json::Arr(r.iter().map(|&x| Json::Num(x)).collect())
                        })
                        .collect(),
                )
            }
            TaskPrediction::Labels { labels, .. } => {
                Json::Arr(labels.iter().map(|&l| Json::Num(l as f64)).collect())
            }
        }
    }

    /// Number of query points predicted for.
    pub fn len(&self) -> usize {
        match self {
            TaskPrediction::Values(v) => v.len(),
            TaskPrediction::Matrix(rows) => rows.len(),
            TaskPrediction::Embeddings(rows) => rows.len(),
            TaskPrediction::Labels { labels, .. } => labels.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// `b(z) = [k(z, x_{Λ(t)})]` over the selected points — the only kernel
/// evaluations any task prediction performs. One shared helper so every
/// front end (CLI, live session, loaded artifact) computes identical
/// bits.
pub fn landmark_row(
    kernel: &dyn Kernel,
    selected: &Dataset,
    z: &[f64],
) -> Result<Vec<f64>> {
    if z.len() != selected.dim() {
        bail!(
            "query point has dimension {} but the model's landmarks have {}",
            z.len(),
            selected.dim()
        );
    }
    Ok((0..selected.n()).map(|t| kernel.eval(z, selected.point(t))).collect())
}

/// [`landmark_row`] blocked: the B×k landmark matrix for a batch of
/// query points, one [`Kernel::eval_rows`] sweep over the contiguous
/// selected-point storage per query point instead of B·k virtual `eval`
/// calls. `eval_rows` is contractually bit-identical to the per-entry
/// loop (tested per kernel), so row i of the result carries exactly
/// `landmark_row(kernel, selected, &points[i])`'s bits — the serving
/// batch path and the historical single-point path cannot drift.
pub fn landmark_block(
    kernel: &dyn Kernel,
    selected: &Dataset,
    points: &[Vec<f64>],
) -> Result<Mat> {
    let (k, dim) = (selected.n(), selected.dim());
    let rows = selected.flat();
    let mut out = Mat::zeros(points.len(), k);
    for (i, z) in points.iter().enumerate() {
        if z.len() != dim {
            bail!(
                "query point {i} has dimension {} but the model's landmarks \
                 have {dim}",
                z.len()
            );
        }
        kernel.eval_rows(rows, dim, z, out.row_mut(i));
    }
    Ok(out)
}

/// [`landmark_block`] for the f32 serving mode: kernel entries are cast
/// to f32 as they are produced, yielding the row-major B×k block the
/// f32 predictor consumes. Returns `(block, k)`.
pub fn landmark_block_f32(
    kernel: &dyn Kernel,
    selected: &Dataset,
    points: &[Vec<f64>],
) -> Result<(Vec<f32>, usize)> {
    let (k, dim) = (selected.n(), selected.dim());
    let rows = selected.flat();
    let mut scratch = vec![0.0f64; k];
    let mut out = Vec::with_capacity(points.len() * k);
    for (i, z) in points.iter().enumerate() {
        if z.len() != dim {
            bail!(
                "query point {i} has dimension {} but the model's landmarks \
                 have {dim}",
                z.len()
            );
        }
        kernel.eval_rows(rows, dim, z, &mut scratch);
        out.extend(scratch.iter().map(|&v| v as f32));
    }
    Ok((out, k))
}

impl FittedTask {
    pub fn kind(&self) -> TaskKind {
        match self {
            FittedTask::Krr(_) => TaskKind::Krr,
            FittedTask::Kpca(_) => TaskKind::Kpca,
            FittedTask::Cluster(_) => TaskKind::Cluster,
        }
    }

    /// Fit `cfg`'s task on an approximation. O(nk² + k³) for every task;
    /// the full n×n G̃ is never formed.
    pub fn fit(approx: &NystromApprox, cfg: &TaskConfig) -> Result<TaskFit> {
        let _span = crate::obs::span("task_fit", "tasks");
        cfg.validate()?;
        Ok(match cfg.kind {
            TaskKind::Krr => {
                let ys = cfg.labels.as_deref().ok_or_else(|| {
                    anyhow!("krr needs training labels (one per data point)")
                })?;
                TaskFit {
                    model: FittedTask::Krr(KrrModel::fit_multi(
                        approx, ys, cfg.ridge,
                    )?),
                    cluster_labels: None,
                }
            }
            TaskKind::Kpca => {
                let (model, _embedding) = KpcaModel::fit(approx, cfg.components)?;
                TaskFit { model: FittedTask::Kpca(model), cluster_labels: None }
            }
            TaskKind::Cluster => {
                let (model, labels) = ClusterModel::fit(
                    approx,
                    cfg.clusters,
                    cfg.components,
                    cfg.seed,
                )?;
                TaskFit {
                    model: FittedTask::Cluster(model),
                    cluster_labels: Some(labels),
                }
            }
        })
    }

    /// Predict for a batch of query points, dataset-free: only the k
    /// selected points are evaluated against (`selected` row t must be
    /// the point of factor column t — a session's dataset selection or
    /// an artifact's stored `Z_Λ`).
    ///
    /// This is the serving hot path, and it is *blocked*: the B×k
    /// landmark matrix is built with one [`landmark_block`] kernel sweep
    /// per point, and KRR values come from a single B×k matvec/matmul
    /// against β instead of a per-point `landmark_row` loop. Because
    /// both blocks are bit-identical to their per-point equivalents (see
    /// [`landmark_block`] and [`KrrModel::predict_block`]), a B = 1
    /// request returns exactly the bits this method always has.
    pub fn predict(
        &self,
        kernel: &dyn Kernel,
        selected: &Dataset,
        points: &[Vec<f64>],
    ) -> Result<TaskPrediction> {
        let _span = crate::obs::span("task_predict", "tasks");
        self.check_landmarks(selected)?;
        let block = landmark_block(kernel, selected, points)?;
        Ok(match self {
            FittedTask::Krr(m) => {
                let values = m.predict_block(&block);
                if m.outputs == 1 {
                    TaskPrediction::Values(values.data)
                } else {
                    TaskPrediction::Matrix(
                        (0..values.rows)
                            .map(|i| values.row(i).to_vec())
                            .collect(),
                    )
                }
            }
            FittedTask::Kpca(m) => TaskPrediction::Embeddings(
                (0..block.rows).map(|i| m.project_row(block.row(i))).collect(),
            ),
            FittedTask::Cluster(m) => {
                let mut labels = Vec::with_capacity(points.len());
                let mut embeddings = Vec::with_capacity(points.len());
                for i in 0..block.rows {
                    let (l, e) = m.assign_row(block.row(i));
                    labels.push(l);
                    embeddings.push(e);
                }
                TaskPrediction::Labels { labels, embeddings }
            }
        })
    }

    /// The f32 serving mode: landmark block and matvec both run in
    /// single precision ([`landmark_block_f32`],
    /// [`KrrModel::predict_block_f32`]), values are widened back to f64
    /// only for the response. KRR only — the eigen-space tasks have no
    /// f32 path — and opt-in per request: expect values to differ from
    /// the f64 path at single-precision scale (~1e-6 relative; worse for
    /// ill-conditioned β).
    pub fn predict_f32(
        &self,
        kernel: &dyn Kernel,
        selected: &Dataset,
        points: &[Vec<f64>],
    ) -> Result<TaskPrediction> {
        let _span = crate::obs::span("task_predict_f32", "tasks");
        self.check_landmarks(selected)?;
        let m = match self {
            FittedTask::Krr(m) => m,
            other => bail!(
                "f32 prediction is only available for krr models (got {})",
                other.kind().as_str()
            ),
        };
        let (block, _k) = landmark_block_f32(kernel, selected, points)?;
        let beta = m.beta_f32();
        let flat = m.predict_block_f32(&block, &beta);
        Ok(if m.outputs == 1 {
            TaskPrediction::Values(flat.iter().map(|&v| v as f64).collect())
        } else {
            TaskPrediction::Matrix(
                flat.chunks_exact(m.outputs)
                    .map(|r| r.iter().map(|&v| v as f64).collect())
                    .collect(),
            )
        })
    }

    /// The landmark count k the model was fit with.
    pub fn k(&self) -> usize {
        match self {
            FittedTask::Krr(m) => m.k(),
            FittedTask::Kpca(m) => m.proj.rows,
            FittedTask::Cluster(m) => m.embedding.proj.rows,
        }
    }

    /// Outputs per query point (KRR label columns; 1 for every other
    /// task).
    pub fn outputs(&self) -> usize {
        match self {
            FittedTask::Krr(m) => m.outputs,
            _ => 1,
        }
    }

    fn check_landmarks(&self, selected: &Dataset) -> Result<()> {
        if selected.n() != self.k() {
            bail!(
                "model was fit with k = {} landmarks but {} selected points \
                 were supplied",
                self.k(),
                selected.n()
            );
        }
        Ok(())
    }

    /// Fit-summary JSON (shared by the CLI report and the server
    /// response).
    pub fn summary_json(&self) -> Json {
        match self {
            FittedTask::Krr(m) => Json::obj(vec![
                ("task", Json::Str("krr".into())),
                ("k", Json::Num(m.k() as f64)),
                ("outputs", Json::Num(m.outputs as f64)),
                ("ridge", Json::Num(m.lambda)),
                ("train_rmse", Json::Num(m.train_rmse)),
            ]),
            FittedTask::Kpca(m) => Json::obj(vec![
                ("task", Json::Str("kpca".into())),
                ("k", Json::Num(m.proj.rows as f64)),
                ("components", Json::Num(m.vals.len() as f64)),
                (
                    "eigenvalues",
                    Json::Arr(m.vals.iter().map(|&v| Json::Num(v)).collect()),
                ),
            ]),
            FittedTask::Cluster(m) => Json::obj(vec![
                ("task", Json::Str("cluster".into())),
                ("k", Json::Num(m.embedding.proj.rows as f64)),
                ("clusters", Json::Num(m.centroids.rows as f64)),
                ("components", Json::Num(m.embedding.vals.len() as f64)),
                ("seed", Json::Num(m.seed as f64)),
            ]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::Gaussian;
    use crate::sampling::{assemble_from_indices, ImplicitOracle};

    fn approx_of(n: usize) -> (NystromApprox, Dataset, Gaussian) {
        let ds = two_moons(n, 0.05, 5);
        let kern = Gaussian::new(0.6);
        let approx = {
            let oracle = ImplicitOracle::new(&ds, &kern);
            let idx: Vec<usize> = (0..n).step_by(3).collect();
            assemble_from_indices(&oracle, idx, 0.0)
        };
        (approx, ds, kern)
    }

    #[test]
    fn kind_spellings_round_trip() {
        for k in [TaskKind::Krr, TaskKind::Kpca, TaskKind::Cluster] {
            assert_eq!(TaskKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(TaskKind::parse("magic").is_err());
    }

    #[test]
    fn config_validation() {
        let mut krr = TaskConfig::new(TaskKind::Krr);
        assert!(krr.validate().is_err(), "labels required");
        krr.labels = Some(vec![vec![0.0; 4]]);
        assert!(krr.validate().is_ok());
        krr.labels = Some(vec![]);
        assert!(krr.validate().is_err(), "at least one label column");
        krr.labels = Some(vec![vec![0.0; 4], vec![0.0; 3]]);
        assert!(krr.validate().is_err(), "ragged label columns");
        krr.labels = Some(vec![vec![0.0; 4]]);
        krr.ridge = 0.0;
        assert!(krr.validate().is_err(), "ridge must be > 0");

        let mut kpca = TaskConfig::new(TaskKind::Kpca);
        kpca.components = 0;
        assert!(kpca.validate().is_err());

        let mut cl = TaskConfig::new(TaskKind::Cluster);
        cl.clusters = 1;
        assert!(cl.validate().is_err());
    }

    #[test]
    fn fit_dispatches_and_predicts_every_kind() {
        let (approx, ds, kern) = approx_of(60);
        let selected = ds.select(&approx.indices);
        let labels: Vec<f64> = (0..60).map(|i| (i % 2) as f64).collect();
        let points = vec![vec![0.4, 0.1], vec![-0.5, 0.3]];

        let mut cfg = TaskConfig::new(TaskKind::Krr);
        cfg.labels = Some(vec![labels]);
        let fit = FittedTask::fit(&approx, &cfg).unwrap();
        assert_eq!(fit.model.kind(), TaskKind::Krr);
        match fit.model.predict(&kern, &selected, &points).unwrap() {
            TaskPrediction::Values(v) => assert_eq!(v.len(), 2),
            other => panic!("unexpected prediction {other:?}"),
        }

        let cfg = TaskConfig::new(TaskKind::Kpca);
        let fit = FittedTask::fit(&approx, &cfg).unwrap();
        match fit.model.predict(&kern, &selected, &points).unwrap() {
            TaskPrediction::Embeddings(rows) => {
                assert_eq!(rows.len(), 2);
                assert_eq!(rows[0].len(), 2);
            }
            other => panic!("unexpected prediction {other:?}"),
        }

        let cfg = TaskConfig::new(TaskKind::Cluster);
        let fit = FittedTask::fit(&approx, &cfg).unwrap();
        let labels = fit.cluster_labels.expect("in-sample labels");
        assert_eq!(labels.len(), 60);
        match fit.model.predict(&kern, &selected, &points).unwrap() {
            TaskPrediction::Labels { labels, embeddings } => {
                assert_eq!(labels.len(), 2);
                assert_eq!(embeddings.len(), 2);
            }
            other => panic!("unexpected prediction {other:?}"),
        }

        // landmark-count and dimension mismatches are clean errors
        let wrong = ds.select(&approx.indices[..3]);
        assert!(fit.model.predict(&kern, &wrong, &points).is_err());
        assert!(fit
            .model
            .predict(&kern, &selected, &[vec![1.0]])
            .is_err());
    }

    /// The blocked landmark matrix must carry exactly `landmark_row`'s
    /// bits per row — the serving batch path and the single-point path
    /// are the same numbers, not merely close ones.
    #[test]
    fn landmark_block_bit_equals_landmark_row() {
        let (approx, ds, kern) = approx_of(45);
        let selected = ds.select(&approx.indices);
        let points: Vec<Vec<f64>> =
            (0..9).map(|i| ds.point(i * 5).to_vec()).collect();
        let block = landmark_block(&kern, &selected, &points).unwrap();
        assert_eq!((block.rows, block.cols), (9, selected.n()));
        for (i, z) in points.iter().enumerate() {
            let row = landmark_row(&kern, &selected, z).unwrap();
            for (a, b) in block.row(i).iter().zip(&row) {
                assert_eq!(a.to_bits(), b.to_bits(), "row {i}");
            }
        }
        // dimension mismatch anywhere in the batch is a clean error
        assert!(landmark_block(&kern, &selected, &[vec![1.0]]).is_err());
    }

    /// A KRR batch of B points must be bit-identical to B single-point
    /// predictions — the acceptance bar for the blocked serving path.
    #[test]
    fn krr_batched_predict_bit_equals_looped() {
        let (approx, ds, kern) = approx_of(60);
        let selected = ds.select(&approx.indices);
        let labels: Vec<f64> = (0..60).map(|i| ((i * 7) % 5) as f64).collect();
        let mut cfg = TaskConfig::new(TaskKind::Krr);
        cfg.labels = Some(vec![labels]);
        let fit = FittedTask::fit(&approx, &cfg).unwrap();
        let points: Vec<Vec<f64>> =
            (0..24).map(|i| ds.point((i * 2) % 60).to_vec()).collect();
        let batched = match fit.model.predict(&kern, &selected, &points).unwrap()
        {
            TaskPrediction::Values(v) => v,
            other => panic!("unexpected prediction {other:?}"),
        };
        let m = match &fit.model {
            FittedTask::Krr(m) => m,
            _ => unreachable!(),
        };
        for (i, z) in points.iter().enumerate() {
            let one =
                m.predict_row(&landmark_row(&kern, &selected, z).unwrap());
            assert_eq!(batched[i].to_bits(), one.to_bits(), "point {i}");
        }
    }

    /// Multi-output fits share one factorization; each output's column
    /// of the batched prediction matrix must match a dedicated
    /// single-output fit on that label column (same factors, same λ ⇒
    /// same β, up to the blocked-matmul accumulation order).
    #[test]
    fn multi_output_krr_matches_per_output_fits() {
        let (approx, ds, kern) = approx_of(60);
        let selected = ds.select(&approx.indices);
        let y0: Vec<f64> = (0..60).map(|i| (i % 2) as f64).collect();
        let y1: Vec<f64> =
            (0..60).map(|i| (i as f64 * 0.31).sin()).collect();
        let mut cfg = TaskConfig::new(TaskKind::Krr);
        cfg.labels = Some(vec![y0.clone(), y1.clone()]);
        let fit = FittedTask::fit(&approx, &cfg).unwrap();
        assert_eq!(fit.model.outputs(), 2);
        assert_eq!(fit.model.k(), selected.n());
        let multi = match &fit.model {
            FittedTask::Krr(m) => m.clone(),
            _ => unreachable!(),
        };
        let solo0 = KrrModel::fit(&approx, &y0, cfg.ridge).unwrap();
        let solo1 = KrrModel::fit(&approx, &y1, cfg.ridge).unwrap();
        // the shared factorization reproduces each dedicated fit's β bits
        for (a, b) in multi.output_beta(0).iter().zip(&solo0.beta) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in multi.output_beta(1).iter().zip(&solo1.beta) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let points = vec![ds.point(3).to_vec(), ds.point(40).to_vec()];
        let rows = match fit.model.predict(&kern, &selected, &points).unwrap()
        {
            TaskPrediction::Matrix(rows) => rows,
            other => panic!("unexpected prediction {other:?}"),
        };
        assert_eq!((rows.len(), rows[0].len()), (2, 2));
        for (i, z) in points.iter().enumerate() {
            let b = landmark_row(&kern, &selected, z).unwrap();
            let want0 = solo0.predict_row(&b);
            let want1 = solo1.predict_row(&b);
            // blocked matmul may re-associate; agreement is to rounding
            assert!((rows[i][0] - want0).abs() < 1e-10, "point {i} out 0");
            assert!((rows[i][1] - want1).abs() < 1e-10, "point {i} out 1");
        }
    }

    /// The f32 serving path tracks the f64 path to single-precision
    /// tolerance, and refuses non-KRR models cleanly.
    #[test]
    fn f32_predict_parity_and_guards() {
        let (approx, ds, kern) = approx_of(60);
        let selected = ds.select(&approx.indices);
        let labels: Vec<f64> = (0..60).map(|i| (i % 3) as f64).collect();
        let mut cfg = TaskConfig::new(TaskKind::Krr);
        cfg.labels = Some(vec![labels]);
        let fit = FittedTask::fit(&approx, &cfg).unwrap();
        let points: Vec<Vec<f64>> =
            (0..17).map(|i| ds.point(i * 3).to_vec()).collect();
        let f64v = match fit.model.predict(&kern, &selected, &points).unwrap()
        {
            TaskPrediction::Values(v) => v,
            other => panic!("unexpected prediction {other:?}"),
        };
        let f32v = match fit
            .model
            .predict_f32(&kern, &selected, &points)
            .unwrap()
        {
            TaskPrediction::Values(v) => v,
            other => panic!("unexpected prediction {other:?}"),
        };
        let scale = fit
            .model
            .k() as f64
            * match &fit.model {
                FittedTask::Krr(m) => {
                    m.beta.iter().fold(0.0f64, |a, &b| a.max(b.abs()))
                }
                _ => unreachable!(),
            };
        for (a, b) in f64v.iter().zip(&f32v) {
            assert!(
                (a - b).abs() <= 1e-5 * scale.max(1.0),
                "{a} vs {b} (scale {scale})"
            );
        }
        // non-KRR models have no f32 path
        let kp = FittedTask::fit(&approx, &TaskConfig::new(TaskKind::Kpca))
            .unwrap();
        assert!(kp.model.predict_f32(&kern, &selected, &points).is_err());
    }
}
