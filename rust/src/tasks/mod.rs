//! Downstream tasks on top of a Nyström approximation — the consumer
//! layer the paper motivates in its opening line: kernel matrices are
//! "essential for many state-of-the-art approaches to classification,
//! clustering, and dimensionality reduction". This module runs exactly
//! those three workloads on an approximation **without ever
//! materializing the full kernel matrix**:
//!
//! * [`krr`] — Nyström kernel ridge regression: dual weights fit from
//!   the rank-k factors in O(nk²), out-of-sample prediction through the
//!   extension machinery (`f(z) = b(z)ᵀ β`, touching only the k selected
//!   points).
//! * [`kpca`] — kernel PCA / spectral embedding: top-d eigenpairs of G̃
//!   via [`nystrom_eig`](crate::nystrom::nystrom_eig), projecting both
//!   in-sample and out-of-sample points.
//! * [`cluster`] — spectral k-means on the embedding, reusing the
//!   k-means machinery from [`crate::sampling::kmeans`].
//!
//! Every fit consumes only `(C, W⁻¹, indices)` — a live session
//! snapshot, a finished run, or a loaded [`StoredArtifact`] all work,
//! and the artifact case is **dataset-free**: prediction evaluates the
//! kernel against the k stored selected points only, exactly like the
//! extension queries. Fits are deterministic functions of the factor
//! bits, so the CLI (`oasis task`), a live server session
//! (`POST /sessions/{name}/task`), and a loaded artifact
//! (`POST /artifacts/{name}/task`) produce bit-identical models and
//! predictions from the same approximation.
//!
//! Fitted models persist: the artifact store appends a versioned `task`
//! section ([`crate::nystrom::store`]), so a `sample → save → fit →
//! predict` pipeline can hand its model to a process that has neither
//! the dataset nor the labels (`examples/krr_pipeline.rs`).
//!
//! [`StoredArtifact`]: crate::nystrom::StoredArtifact

pub mod cluster;
pub mod kpca;
pub mod krr;

pub use cluster::ClusterModel;
pub use kpca::KpcaModel;
pub use krr::KrrModel;

use crate::data::Dataset;
use crate::kernels::Kernel;
use crate::nystrom::NystromApprox;
use crate::util::json::Json;
use crate::Result;
use crate::{anyhow, bail};

/// Which downstream task to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// Kernel ridge regression (needs labels).
    Krr,
    /// Kernel PCA / spectral embedding.
    Kpca,
    /// Spectral k-means clustering on the embedding.
    Cluster,
}

impl TaskKind {
    pub fn parse(s: &str) -> Result<TaskKind> {
        Ok(match s {
            "krr" => TaskKind::Krr,
            "kpca" => TaskKind::Kpca,
            "cluster" => TaskKind::Cluster,
            other => bail!("unknown task '{other}' (expected krr|kpca|cluster)"),
        })
    }

    /// The canonical spelling [`parse`](TaskKind::parse) accepts.
    pub fn as_str(self) -> &'static str {
        match self {
            TaskKind::Krr => "krr",
            TaskKind::Kpca => "kpca",
            TaskKind::Cluster => "cluster",
        }
    }

    /// The shared CLI/server default embedding dimensionality: one
    /// dimension per cluster for the cluster task, 2 otherwise. (In one
    /// place so the front ends cannot drift.)
    pub fn default_components(self, clusters: usize) -> usize {
        match self {
            TaskKind::Cluster => clusters,
            TaskKind::Krr | TaskKind::Kpca => 2,
        }
    }
}

/// A fully resolved task configuration — labels already loaded, every
/// parameter validated. The engine resolves a
/// [`TaskSpec`](crate::engine::TaskSpec) (which still holds file paths)
/// into this; tests and the library construct it directly.
#[derive(Clone, Debug)]
pub struct TaskConfig {
    pub kind: TaskKind,
    /// Ridge λ (KRR; must be > 0 — λ = 0 would invert a singular G̃).
    pub ridge: f64,
    /// Embedding dimensions d (KPCA, and the spectral-cluster embedding).
    pub components: usize,
    /// Cluster count (cluster task).
    pub clusters: usize,
    /// K-means seeding RNG (cluster task).
    pub seed: u64,
    /// Training labels, one per data point (KRR only).
    pub labels: Option<Vec<f64>>,
}

impl TaskConfig {
    /// A config with the CLI/server defaults for `kind`; set the fields
    /// the task reads before fitting.
    pub fn new(kind: TaskKind) -> TaskConfig {
        TaskConfig {
            kind,
            ridge: 1e-3,
            components: 2,
            clusters: 2,
            seed: 7,
            labels: None,
        }
    }

    /// Validate the parameters the task will read. (Label length is
    /// checked against n at fit time.)
    pub fn validate(&self) -> Result<()> {
        match self.kind {
            TaskKind::Krr => {
                if !(self.ridge.is_finite() && self.ridge > 0.0) {
                    bail!("krr ridge must be a finite number > 0");
                }
                if self.labels.is_none() {
                    bail!("krr needs training labels (one per data point)");
                }
            }
            TaskKind::Kpca => {
                if self.components == 0 {
                    bail!("kpca needs components ≥ 1");
                }
            }
            TaskKind::Cluster => {
                if self.clusters < 2 {
                    bail!("cluster needs clusters ≥ 2");
                }
                if self.components == 0 {
                    bail!("cluster needs components ≥ 1");
                }
            }
        }
        Ok(())
    }
}

/// A fitted downstream model. Everything a model holds lives in the
/// k-dimensional landmark space (plus d-dimensional embedding state), so
/// prediction needs only the kernel and the k selected points — the same
/// dataset-free contract as the artifact extension queries.
#[derive(Clone, Debug)]
pub enum FittedTask {
    Krr(KrrModel),
    Kpca(KpcaModel),
    Cluster(ClusterModel),
}

/// A fit plus its in-sample by-products (reported once, not stored in
/// the model: they are O(n)).
#[derive(Clone, Debug)]
pub struct TaskFit {
    pub model: FittedTask,
    /// In-sample cluster labels (cluster task only).
    pub cluster_labels: Option<Vec<usize>>,
}

/// Per-point predictions, shaped by the task.
#[derive(Clone, Debug)]
pub enum TaskPrediction {
    /// KRR: one regression value per query point.
    Values(Vec<f64>),
    /// KPCA: one d-vector of embedding coordinates per query point.
    Embeddings(Vec<Vec<f64>>),
    /// Cluster: one label per query point, plus its embedding.
    Labels { labels: Vec<usize>, embeddings: Vec<Vec<f64>> },
}

impl TaskPrediction {
    /// The `"predictions"` JSON value (shared by the CLI and the server,
    /// so their rendered predictions are byte-identical).
    pub fn to_json(&self) -> Json {
        match self {
            TaskPrediction::Values(v) => {
                Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
            }
            TaskPrediction::Embeddings(rows) => Json::Arr(
                rows.iter()
                    .map(|r| Json::Arr(r.iter().map(|&x| Json::Num(x)).collect()))
                    .collect(),
            ),
            TaskPrediction::Labels { labels, .. } => {
                Json::Arr(labels.iter().map(|&l| Json::Num(l as f64)).collect())
            }
        }
    }
}

/// `b(z) = [k(z, x_{Λ(t)})]` over the selected points — the only kernel
/// evaluations any task prediction performs. One shared helper so every
/// front end (CLI, live session, loaded artifact) computes identical
/// bits.
pub fn landmark_row(
    kernel: &dyn Kernel,
    selected: &Dataset,
    z: &[f64],
) -> Result<Vec<f64>> {
    if z.len() != selected.dim() {
        bail!(
            "query point has dimension {} but the model's landmarks have {}",
            z.len(),
            selected.dim()
        );
    }
    Ok((0..selected.n()).map(|t| kernel.eval(z, selected.point(t))).collect())
}

impl FittedTask {
    pub fn kind(&self) -> TaskKind {
        match self {
            FittedTask::Krr(_) => TaskKind::Krr,
            FittedTask::Kpca(_) => TaskKind::Kpca,
            FittedTask::Cluster(_) => TaskKind::Cluster,
        }
    }

    /// Fit `cfg`'s task on an approximation. O(nk² + k³) for every task;
    /// the full n×n G̃ is never formed.
    pub fn fit(approx: &NystromApprox, cfg: &TaskConfig) -> Result<TaskFit> {
        let _span = crate::obs::span("task_fit", "tasks");
        cfg.validate()?;
        Ok(match cfg.kind {
            TaskKind::Krr => {
                let y = cfg.labels.as_deref().ok_or_else(|| {
                    anyhow!("krr needs training labels (one per data point)")
                })?;
                TaskFit {
                    model: FittedTask::Krr(KrrModel::fit(approx, y, cfg.ridge)?),
                    cluster_labels: None,
                }
            }
            TaskKind::Kpca => {
                let (model, _embedding) = KpcaModel::fit(approx, cfg.components)?;
                TaskFit { model: FittedTask::Kpca(model), cluster_labels: None }
            }
            TaskKind::Cluster => {
                let (model, labels) = ClusterModel::fit(
                    approx,
                    cfg.clusters,
                    cfg.components,
                    cfg.seed,
                )?;
                TaskFit {
                    model: FittedTask::Cluster(model),
                    cluster_labels: Some(labels),
                }
            }
        })
    }

    /// Predict for a batch of query points, dataset-free: only the k
    /// selected points are evaluated against (`selected` row t must be
    /// the point of factor column t — a session's dataset selection or
    /// an artifact's stored `Z_Λ`).
    pub fn predict(
        &self,
        kernel: &dyn Kernel,
        selected: &Dataset,
        points: &[Vec<f64>],
    ) -> Result<TaskPrediction> {
        let _span = crate::obs::span("task_predict", "tasks");
        self.check_landmarks(selected)?;
        Ok(match self {
            FittedTask::Krr(m) => {
                let mut out = Vec::with_capacity(points.len());
                for z in points {
                    out.push(m.predict_row(&landmark_row(kernel, selected, z)?));
                }
                TaskPrediction::Values(out)
            }
            FittedTask::Kpca(m) => {
                let mut out = Vec::with_capacity(points.len());
                for z in points {
                    out.push(m.project_row(&landmark_row(kernel, selected, z)?));
                }
                TaskPrediction::Embeddings(out)
            }
            FittedTask::Cluster(m) => {
                let mut labels = Vec::with_capacity(points.len());
                let mut embeddings = Vec::with_capacity(points.len());
                for z in points {
                    let (l, e) = m.assign_row(&landmark_row(kernel, selected, z)?);
                    labels.push(l);
                    embeddings.push(e);
                }
                TaskPrediction::Labels { labels, embeddings }
            }
        })
    }

    /// The landmark count k the model was fit with.
    pub fn k(&self) -> usize {
        match self {
            FittedTask::Krr(m) => m.beta.len(),
            FittedTask::Kpca(m) => m.proj.rows,
            FittedTask::Cluster(m) => m.embedding.proj.rows,
        }
    }

    fn check_landmarks(&self, selected: &Dataset) -> Result<()> {
        if selected.n() != self.k() {
            bail!(
                "model was fit with k = {} landmarks but {} selected points \
                 were supplied",
                self.k(),
                selected.n()
            );
        }
        Ok(())
    }

    /// Fit-summary JSON (shared by the CLI report and the server
    /// response).
    pub fn summary_json(&self) -> Json {
        match self {
            FittedTask::Krr(m) => Json::obj(vec![
                ("task", Json::Str("krr".into())),
                ("k", Json::Num(m.beta.len() as f64)),
                ("ridge", Json::Num(m.lambda)),
                ("train_rmse", Json::Num(m.train_rmse)),
            ]),
            FittedTask::Kpca(m) => Json::obj(vec![
                ("task", Json::Str("kpca".into())),
                ("k", Json::Num(m.proj.rows as f64)),
                ("components", Json::Num(m.vals.len() as f64)),
                (
                    "eigenvalues",
                    Json::Arr(m.vals.iter().map(|&v| Json::Num(v)).collect()),
                ),
            ]),
            FittedTask::Cluster(m) => Json::obj(vec![
                ("task", Json::Str("cluster".into())),
                ("k", Json::Num(m.embedding.proj.rows as f64)),
                ("clusters", Json::Num(m.centroids.rows as f64)),
                ("components", Json::Num(m.embedding.vals.len() as f64)),
                ("seed", Json::Num(m.seed as f64)),
            ]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::Gaussian;
    use crate::sampling::{assemble_from_indices, ImplicitOracle};

    fn approx_of(n: usize) -> (NystromApprox, Dataset, Gaussian) {
        let ds = two_moons(n, 0.05, 5);
        let kern = Gaussian::new(0.6);
        let approx = {
            let oracle = ImplicitOracle::new(&ds, &kern);
            let idx: Vec<usize> = (0..n).step_by(3).collect();
            assemble_from_indices(&oracle, idx, 0.0)
        };
        (approx, ds, kern)
    }

    #[test]
    fn kind_spellings_round_trip() {
        for k in [TaskKind::Krr, TaskKind::Kpca, TaskKind::Cluster] {
            assert_eq!(TaskKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(TaskKind::parse("magic").is_err());
    }

    #[test]
    fn config_validation() {
        let mut krr = TaskConfig::new(TaskKind::Krr);
        assert!(krr.validate().is_err(), "labels required");
        krr.labels = Some(vec![0.0; 4]);
        assert!(krr.validate().is_ok());
        krr.ridge = 0.0;
        assert!(krr.validate().is_err(), "ridge must be > 0");

        let mut kpca = TaskConfig::new(TaskKind::Kpca);
        kpca.components = 0;
        assert!(kpca.validate().is_err());

        let mut cl = TaskConfig::new(TaskKind::Cluster);
        cl.clusters = 1;
        assert!(cl.validate().is_err());
    }

    #[test]
    fn fit_dispatches_and_predicts_every_kind() {
        let (approx, ds, kern) = approx_of(60);
        let selected = ds.select(&approx.indices);
        let labels: Vec<f64> = (0..60).map(|i| (i % 2) as f64).collect();
        let points = vec![vec![0.4, 0.1], vec![-0.5, 0.3]];

        let mut cfg = TaskConfig::new(TaskKind::Krr);
        cfg.labels = Some(labels);
        let fit = FittedTask::fit(&approx, &cfg).unwrap();
        assert_eq!(fit.model.kind(), TaskKind::Krr);
        match fit.model.predict(&kern, &selected, &points).unwrap() {
            TaskPrediction::Values(v) => assert_eq!(v.len(), 2),
            other => panic!("unexpected prediction {other:?}"),
        }

        let cfg = TaskConfig::new(TaskKind::Kpca);
        let fit = FittedTask::fit(&approx, &cfg).unwrap();
        match fit.model.predict(&kern, &selected, &points).unwrap() {
            TaskPrediction::Embeddings(rows) => {
                assert_eq!(rows.len(), 2);
                assert_eq!(rows[0].len(), 2);
            }
            other => panic!("unexpected prediction {other:?}"),
        }

        let cfg = TaskConfig::new(TaskKind::Cluster);
        let fit = FittedTask::fit(&approx, &cfg).unwrap();
        let labels = fit.cluster_labels.expect("in-sample labels");
        assert_eq!(labels.len(), 60);
        match fit.model.predict(&kern, &selected, &points).unwrap() {
            TaskPrediction::Labels { labels, embeddings } => {
                assert_eq!(labels.len(), 2);
                assert_eq!(embeddings.len(), 2);
            }
            other => panic!("unexpected prediction {other:?}"),
        }

        // landmark-count and dimension mismatches are clean errors
        let wrong = ds.select(&approx.indices[..3]);
        assert!(fit.model.predict(&kern, &wrong, &points).is_err());
        assert!(fit
            .model
            .predict(&kern, &selected, &[vec![1.0]])
            .is_err());
    }
}
