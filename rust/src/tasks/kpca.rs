//! Kernel PCA / spectral embedding through the Nyström approximation.
//!
//! The top-d eigenpairs `(λⱼ, uⱼ)` of `G̃ = C W⁺ Cᵀ` come from
//! [`nystrom_eig`](crate::nystrom::nystrom_eig) at O(nk² + k³); the
//! in-sample embedding of point i is row i of the orthonormal
//! eigenvector matrix `U` (n×d). Out-of-sample points project through
//! the Nyström extension of the eigenfunctions:
//!
//! ```text
//! φⱼ(z) = (1/λⱼ) ĝ(z, ·) uⱼ = b(z)ᵀ [W⁻¹ Cᵀ U diag(1/λ)]ⱼ
//! ```
//!
//! so the model stores only the k×d projection matrix `P = W⁻¹ Cᵀ U
//! diag(1/λ)` and embeds any point as `b(z)ᵀ P` — k kernel evaluations
//! against the selected points, no dataset required. At an in-sample
//! point the projection reproduces that point's embedding row (up to
//! rounding), because `b(xᵢ)` is exactly `C(i,·)`.

use crate::linalg::Mat;
use crate::nystrom::{nystrom_eig, NystromApprox};
use crate::Result;
use crate::bail;

/// A fitted kernel-PCA embedding: eigenvalues and the landmark-space
/// projection (`embed(z) = b(z)ᵀ proj`).
#[derive(Clone, Debug)]
pub struct KpcaModel {
    /// Retained eigenvalues of G̃, descending (d ≤ requested components,
    /// capped by the approximation's numerical rank).
    pub vals: Vec<f64>,
    /// k×d out-of-sample projection `W⁻¹ Cᵀ U diag(1/λ)`.
    pub proj: Mat,
}

impl KpcaModel {
    /// Fit the top-`components` eigenpairs; returns the model and the
    /// n×d in-sample embedding (orthonormal columns). The embedding is
    /// returned rather than stored — it is O(n·d) and cheap to
    /// recompute from the factors.
    pub fn fit(
        approx: &NystromApprox,
        components: usize,
    ) -> Result<(KpcaModel, Mat)> {
        if components == 0 {
            bail!("kpca: components must be ≥ 1");
        }
        let (vals, u) = nystrom_eig(approx, 1e-12);
        if vals.is_empty() {
            bail!("kpca: the approximation has no positive eigenvalues");
        }
        let d = components.min(vals.len());
        let keep: Vec<usize> = (0..d).collect();
        let u_d = u.select_cols(&keep); // n×d
        let vals_d = vals[..d].to_vec();
        // P = W⁻¹ (Cᵀ U) diag(1/λ)
        let ctu = approx.c.t_matmul(&u_d); // k×d, no n×k transpose copy
        let mut proj = approx.winv.matmul(&ctu); // k×d
        for (j, &l) in vals_d.iter().enumerate() {
            let inv = 1.0 / l;
            for t in 0..proj.rows {
                *proj.at_mut(t, j) *= inv;
            }
        }
        Ok((KpcaModel { vals: vals_d, proj }, u_d))
    }

    /// Number of embedding dimensions d.
    pub fn dims(&self) -> usize {
        self.vals.len()
    }

    /// Embed one point from its landmark row
    /// ([`landmark_row`](super::landmark_row)): `b(z)ᵀ proj`.
    pub fn project_row(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(b.len(), self.proj.rows, "kpca: landmark row length");
        (0..self.proj.cols)
            .map(|j| (0..self.proj.rows).map(|t| b[t] * self.proj.at(t, j)).sum())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::kernels::Gaussian;
    use crate::linalg::matrix::dot;
    use crate::sampling::{assemble_from_indices, ImplicitOracle};
    use crate::tasks::landmark_row;

    #[test]
    fn embedding_is_orthonormal_and_projection_consistent() {
        let n = 90;
        let ds = two_moons(n, 0.05, 7);
        let kern = Gaussian::with_sigma_fraction(&ds, 0.1);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let idx: Vec<usize> = (0..n).step_by(2).collect();
        let approx = assemble_from_indices(&oracle, idx, 0.0);
        let (model, u) = KpcaModel::fit(&approx, 3).unwrap();
        assert_eq!(model.dims(), 3);
        assert_eq!(u.cols, 3);
        // UᵀU = I
        let utu = u.syrk();
        assert!(utu.fro_dist(&Mat::eye(3)) < 1e-8, "{}", utu.fro_dist(&Mat::eye(3)));
        // the out-of-sample projection of an *in-sample* point reproduces
        // its embedding row (b(xᵢ) = C(i,·) exactly)
        let selected = ds.select(&approx.indices);
        for i in [0usize, 31, 89] {
            let b = landmark_row(&kern, &selected, ds.point(i)).unwrap();
            let e = model.project_row(&b);
            for (j, &got) in e.iter().enumerate() {
                assert!(
                    (got - u.at(i, j)).abs() < 1e-6,
                    "point {i} dim {j}: {got} vs {}",
                    u.at(i, j)
                );
            }
        }
    }

    #[test]
    fn components_capped_by_rank() {
        // a rank-deficient approximation keeps fewer dims than requested
        let ds = two_moons(30, 0.05, 2);
        let kern = Gaussian::new(5.0); // wide kernel → fast spectral decay
        let oracle = ImplicitOracle::new(&ds, &kern);
        let approx = assemble_from_indices(&oracle, vec![0, 10, 20], 0.0);
        let (model, u) = KpcaModel::fit(&approx, 10).unwrap();
        assert!(model.dims() <= 3, "dims {}", model.dims());
        assert_eq!(u.cols, model.dims());
        assert!(KpcaModel::fit(&approx, 0).is_err());
    }

    /// The leading coordinate carries the dominant variance direction:
    /// eigenvalues are sorted descending and positive.
    #[test]
    fn eigenvalues_descend() {
        let ds = two_moons(60, 0.05, 4);
        let kern = Gaussian::with_sigma_fraction(&ds, 0.1);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let idx: Vec<usize> = (0..60).step_by(2).collect();
        let approx = assemble_from_indices(&oracle, idx, 0.0);
        let (model, u) = KpcaModel::fit(&approx, 4).unwrap();
        for w in model.vals.windows(2) {
            assert!(w[0] >= w[1] && w[1] > 0.0);
        }
        // columns are unit vectors
        for j in 0..u.cols {
            let col: Vec<f64> = (0..u.rows).map(|i| u.at(i, j)).collect();
            assert!((dot(&col, &col) - 1.0).abs() < 1e-8);
        }
    }
}
