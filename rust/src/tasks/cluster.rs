//! Spectral clustering on the Nyström embedding — the clustering
//! workload of the paper's opening claim, served from the same rank-k
//! factors as everything else.
//!
//! The pipeline is Ng–Jordan–Weiss-shaped, with the dense affinity
//! eigendecomposition replaced by the O(nk²) Nyström one: embed every
//! point into the top-d eigenvectors of G̃ ([`KpcaModel`]), row-normalize
//! the embedding, and run seeded k-means
//! ([`KMeans`](crate::sampling::kmeans::KMeans) — the same Lloyd +
//! k-means++ machinery the K-means Nyström sampler uses). Out-of-sample
//! points are assigned by projecting through the stored [`KpcaModel`],
//! row-normalizing, and taking the nearest centroid — dataset-free, like
//! every task here.

use super::kpca::KpcaModel;
use crate::data::Dataset;
use crate::linalg::Mat;
use crate::nystrom::NystromApprox;
use crate::sampling::kmeans::KMeans;
use crate::Result;
use crate::bail;

/// A fitted spectral-clustering model: the embedding projection plus the
/// k-means centroids in the row-normalized embedding space.
#[derive(Clone, Debug)]
pub struct ClusterModel {
    /// The spectral embedding out-of-sample points project through.
    pub embedding: KpcaModel,
    /// c×d centroids in the row-normalized embedding space.
    pub centroids: Mat,
    /// K-means seeding RNG (recorded so refits are reproducible).
    pub seed: u64,
}

/// Row-normalize one embedding vector in place (unit ℓ2 norm, with the
/// same 1e-12 floor the SEED spectral clustering uses).
fn normalize_row(e: &mut [f64]) {
    let nrm = e.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
    for x in e {
        *x /= nrm;
    }
}

impl ClusterModel {
    /// Fit: embed into `components` eigenvectors, row-normalize, k-means
    /// into `clusters` groups. Returns the model and the in-sample
    /// labels (one per data point).
    pub fn fit(
        approx: &NystromApprox,
        clusters: usize,
        components: usize,
        seed: u64,
    ) -> Result<(ClusterModel, Vec<usize>)> {
        if clusters < 2 {
            bail!("cluster: clusters must be ≥ 2");
        }
        if clusters > approx.n() {
            bail!("cluster: {} clusters for n = {} points", clusters, approx.n());
        }
        let (embedding, u) = KpcaModel::fit(approx, components)?;
        let (n, d) = (u.rows, u.cols);
        let mut emb = Dataset::zeros(n, d);
        for i in 0..n {
            let row = emb.point_mut(i);
            row.copy_from_slice(u.row(i));
            normalize_row(row);
        }
        let (centroid_ds, labels, _iters) = KMeans::new(clusters, seed).fit(&emb);
        let c = centroid_ds.n();
        let mut centroids = Mat::zeros(c, d);
        for i in 0..c {
            centroids.row_mut(i).copy_from_slice(centroid_ds.point(i));
        }
        Ok((ClusterModel { embedding, centroids, seed }, labels))
    }

    /// Number of clusters c.
    pub fn clusters(&self) -> usize {
        self.centroids.rows
    }

    /// Assign one point from its landmark row
    /// ([`landmark_row`](super::landmark_row)): project, row-normalize,
    /// nearest centroid. Returns `(label, normalized embedding)`.
    pub fn assign_row(&self, b: &[f64]) -> (usize, Vec<f64>) {
        let mut e = self.embedding.project_row(b);
        normalize_row(&mut e);
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        for c in 0..self.centroids.rows {
            let d: f64 = self
                .centroids
                .row(c)
                .iter()
                .zip(&e)
                .map(|(x, y)| (x - y) * (x - y))
                .sum();
            if d < best_d {
                best_d = d;
                best = c;
            }
        }
        (best, e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::gaussian_clusters;
    use crate::kernels::Gaussian;
    use crate::sampling::{assemble_from_indices, ImplicitOracle};
    use crate::seed::permutation_accuracy;
    use crate::tasks::landmark_row;

    fn clustered_setup() -> (NystromApprox, Dataset, Gaussian, Vec<usize>) {
        // 3 tight, well-separated clusters; truth label = i % 3
        let n = 120;
        let ds = gaussian_clusters(n, 4, 3, 0.08, 6);
        let truth: Vec<usize> = (0..n).map(|i| i % 3).collect();
        let kern = Gaussian::new(1.2);
        let approx = {
            let oracle = ImplicitOracle::new(&ds, &kern);
            let idx: Vec<usize> = (0..n).step_by(3).collect();
            assemble_from_indices(&oracle, idx, 0.0)
        };
        (approx, ds, kern, truth)
    }

    #[test]
    fn recovers_separated_clusters() {
        let (approx, _, _, truth) = clustered_setup();
        let (model, labels) = ClusterModel::fit(&approx, 3, 3, 11).unwrap();
        assert_eq!(model.clusters(), 3);
        let acc = permutation_accuracy(&labels, &truth, 3);
        assert!(acc > 0.9, "clustering accuracy {acc}");
    }

    /// Under a fixed seed the fit is fully deterministic: labels and
    /// centroids are bit-identical across refits.
    #[test]
    fn labels_stable_under_fixed_seed() {
        let (approx, _, _, _) = clustered_setup();
        let (m1, l1) = ClusterModel::fit(&approx, 3, 3, 42).unwrap();
        let (m2, l2) = ClusterModel::fit(&approx, 3, 3, 42).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(m1.centroids.data.len(), m2.centroids.data.len());
        for (a, b) in m1.centroids.data.iter().zip(&m2.centroids.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// Out-of-sample assignment of an in-sample point reproduces its
    /// in-sample label (the projection reproduces its embedding row).
    #[test]
    fn assignment_consistent_in_sample() {
        let (approx, ds, kern, _) = clustered_setup();
        let (model, labels) = ClusterModel::fit(&approx, 3, 3, 5).unwrap();
        let selected = ds.select(&approx.indices);
        let mut agree = 0usize;
        let probes: Vec<usize> = (0..ds.n()).step_by(11).collect();
        for &i in &probes {
            let b = landmark_row(&kern, &selected, ds.point(i)).unwrap();
            let (label, e) = model.assign_row(&b);
            assert_eq!(e.len(), model.embedding.dims());
            if label == labels[i] {
                agree += 1;
            }
        }
        assert!(
            agree * 10 >= probes.len() * 9,
            "only {agree}/{} in-sample assignments agreed",
            probes.len()
        );
    }

    #[test]
    fn bad_configs_rejected() {
        let (approx, _, _, _) = clustered_setup();
        assert!(ClusterModel::fit(&approx, 1, 2, 0).is_err());
        assert!(ClusterModel::fit(&approx, 1000, 2, 0).is_err());
        assert!(ClusterModel::fit(&approx, 3, 0, 0).is_err());
    }
}
