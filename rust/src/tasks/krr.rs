//! Nyström kernel ridge regression.
//!
//! Exact KRR solves `α = (G + λI)⁻¹ y` at O(n³) and predicts with the
//! full kernel row of a query point. With the Nyström factors the same
//! dual solve costs O(nk²): writing `G̃ = Φ Φᵀ` with `Φ = C (W⁺)^{1/2}`
//! ([`nystrom_factor`]), the Woodbury identity gives
//!
//! ```text
//! α = (G̃ + λI)⁻¹ y = (y − Φ (λI + ΦᵀΦ)⁻¹ Φᵀ y) / λ
//! ```
//!
//! and the predictor collapses into the **landmark space**: for a query
//! point z the Nyström extension of its kernel row is
//! `ĝ(z, ·) = b(z)ᵀ W⁻¹ Cᵀ`, so
//!
//! ```text
//! f(z) = ĝ(z, ·) α = b(z)ᵀ β   with   β = W⁻¹ Cᵀ α ∈ R^k
//! ```
//!
//! — prediction touches only the k selected points (`b(z)_t =
//! k(z, x_{Λ(t)})`), which is what makes a stored model dataset-free:
//! an artifact's `Z_Λ` and kernel parameters are all it ever needs.

use crate::linalg::{pinv_psd, Cholesky};
use crate::nystrom::{nystrom_factor, NystromApprox};
use crate::Result;
use crate::bail;

/// A fitted Nyström KRR model: the ridge and the landmark-space dual
/// weights β (`f(z) = b(z)ᵀ β`).
#[derive(Clone, Debug)]
pub struct KrrModel {
    /// Ridge λ the model was fit with.
    pub lambda: f64,
    /// Landmark-space dual weights (length k, selection order).
    pub beta: Vec<f64>,
    /// Root-mean-square error of the in-sample fit `C β` against y.
    pub train_rmse: f64,
}

impl KrrModel {
    /// Fit dual weights from the rank-k factors in O(nk²). `y` must hold
    /// one label per data point; `lambda` must be > 0 (λ = 0 would ask
    /// for the pseudo-inverse of a rank-deficient G̃).
    pub fn fit(approx: &NystromApprox, y: &[f64], lambda: f64) -> Result<KrrModel> {
        let (n, k) = (approx.n(), approx.k());
        if y.len() != n {
            bail!("krr: {} labels for n = {n} data points", y.len());
        }
        if !(lambda.is_finite() && lambda > 0.0) {
            bail!("krr: ridge must be a finite number > 0 (got {lambda})");
        }
        if let Some(bad) = y.iter().find(|v| !v.is_finite()) {
            bail!("krr: label {bad} is not finite");
        }
        let phi = nystrom_factor(approx); // n×k
        // A = λI + ΦᵀΦ (k×k, SPD for λ > 0; dedicated Gram kernel)
        let mut a = phi.syrk();
        for i in 0..k {
            *a.at_mut(i, i) += lambda;
        }
        // Φᵀ y / Cᵀ α below use Mat::t_matvec: the n×k factors are the
        // fit's dominant allocation, so nothing may materialize their
        // transpose
        let phity = phi.t_matvec(y);
        let z = match Cholesky::new(&a) {
            Some(ch) => ch.solve(&phity),
            // λ > 0 makes A PD in exact arithmetic; fall back to the
            // pseudo-inverse if rounding starved a pivot anyway
            None => pinv_psd(&a, 1e-14).matvec(&phity),
        };
        // α = (y − Φ z) / λ
        let phiz = phi.matvec(&z);
        let inv_l = 1.0 / lambda;
        let alpha: Vec<f64> =
            y.iter().zip(&phiz).map(|(yi, pi)| (yi - pi) * inv_l).collect();
        // β = W⁻¹ (Cᵀ α): the dual weights moved into landmark space
        let cta = approx.c.t_matvec(&alpha);
        let beta = approx.winv.matvec(&cta);
        // in-sample fit f(xᵢ) = G̃(i,·) α = C(i,·) β
        let fitted = approx.c.matvec(&beta);
        let sse: f64 = fitted
            .iter()
            .zip(y)
            .map(|(f, yi)| (f - yi) * (f - yi))
            .sum();
        Ok(KrrModel {
            lambda,
            beta,
            train_rmse: (sse / n as f64).sqrt(),
        })
    }

    /// `f(z) = b(z)ᵀ β` for a precomputed landmark row
    /// ([`landmark_row`](super::landmark_row)).
    #[inline]
    pub fn predict_row(&self, b: &[f64]) -> f64 {
        crate::linalg::matrix::dot(b, &self.beta)
    }

    /// In-sample predictions `C β` (one per training point) — cheap to
    /// recompute, so they are not stored in the model.
    pub fn predict_in_sample(&self, approx: &NystromApprox) -> Vec<f64> {
        approx.c.matvec(&self.beta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::data::Dataset;
    use crate::kernels::Gaussian;
    use crate::sampling::{assemble_from_indices, ImplicitOracle};
    use crate::tasks::landmark_row;

    fn full_rank_setup() -> (NystromApprox, Dataset, Gaussian, Vec<f64>) {
        let ds = two_moons(40, 0.05, 3);
        // a fairly local kernel keeps G well-conditioned, so the tiny-λ
        // fit below really can interpolate
        let kern = Gaussian::new(0.35);
        let approx = {
            let oracle = ImplicitOracle::new(&ds, &kern);
            assemble_from_indices(&oracle, (0..40).collect(), 0.0)
        };
        // a smooth target: y = sin(2x) + cos(y)
        let y: Vec<f64> = (0..40)
            .map(|i| {
                let p = ds.point(i);
                (2.0 * p[0]).sin() + p[1].cos()
            })
            .collect();
        (approx, ds, kern, y)
    }

    /// With all n columns sampled G̃ = G exactly, so a tiny ridge must
    /// interpolate the training labels almost exactly.
    #[test]
    fn near_interpolation_at_full_rank() {
        let (approx, _, _, y) = full_rank_setup();
        let m = KrrModel::fit(&approx, &y, 1e-8).unwrap();
        assert!(m.train_rmse < 1e-3, "train rmse {}", m.train_rmse);
    }

    /// The landmark-space predictor must agree with the dual-space
    /// in-sample fit: predicting at training point xᵢ via b(xᵢ) equals
    /// row i of C β, because b(xᵢ) is exactly C(i,·).
    #[test]
    fn landmark_prediction_consistent_with_in_sample() {
        let ds = two_moons(60, 0.05, 9);
        let kern = Gaussian::new(0.6);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let idx: Vec<usize> = (0..60).step_by(2).collect();
        let approx = assemble_from_indices(&oracle, idx, 0.0);
        let y: Vec<f64> = (0..60).map(|i| (i % 2) as f64).collect();
        let m = KrrModel::fit(&approx, &y, 1e-3).unwrap();
        let fitted = m.predict_in_sample(&approx);
        let selected = ds.select(&approx.indices);
        for i in (0..60).step_by(7) {
            let b = landmark_row(&kern, &selected, ds.point(i)).unwrap();
            let by_row = m.predict_row(&b);
            assert!(
                (by_row - fitted[i]).abs() < 1e-8,
                "point {i}: {by_row} vs {fitted:?}"
            );
        }
        // predictions generalize: a held-out point near class-1 training
        // points predicts closer to 1 than to 0
        let z = ds.point(1).to_vec();
        let b = landmark_row(&kern, &selected, &z).unwrap();
        let f = m.predict_row(&b);
        assert!(f.is_finite());
    }

    #[test]
    fn ridge_regularizes() {
        let (approx, _, _, y) = full_rank_setup();
        let tight = KrrModel::fit(&approx, &y, 1e-8).unwrap();
        let loose = KrrModel::fit(&approx, &y, 10.0).unwrap();
        assert!(
            tight.train_rmse < loose.train_rmse,
            "{} !< {}",
            tight.train_rmse,
            loose.train_rmse
        );
        let norm = |b: &[f64]| b.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(norm(&loose.beta) < norm(&tight.beta));
    }

    #[test]
    fn bad_inputs_rejected() {
        let (approx, _, _, y) = full_rank_setup();
        assert!(KrrModel::fit(&approx, &y[..10], 1e-3).is_err());
        assert!(KrrModel::fit(&approx, &y, 0.0).is_err());
        assert!(KrrModel::fit(&approx, &y, f64::NAN).is_err());
        let mut bad = y.clone();
        bad[3] = f64::INFINITY;
        assert!(KrrModel::fit(&approx, &bad, 1e-3).is_err());
    }

    /// Fits are deterministic functions of the factor bits: refitting
    /// gives bit-identical β.
    #[test]
    fn fit_is_deterministic() {
        let (approx, _, _, y) = full_rank_setup();
        let a = KrrModel::fit(&approx, &y, 1e-4).unwrap();
        let b = KrrModel::fit(&approx, &y, 1e-4).unwrap();
        assert_eq!(a.beta.len(), b.beta.len());
        for (x, z) in a.beta.iter().zip(&b.beta) {
            assert_eq!(x.to_bits(), z.to_bits());
        }
        assert_eq!(a.train_rmse.to_bits(), b.train_rmse.to_bits());
    }
}
