//! Nyström kernel ridge regression.
//!
//! Exact KRR solves `α = (G + λI)⁻¹ y` at O(n³) and predicts with the
//! full kernel row of a query point. With the Nyström factors the same
//! dual solve costs O(nk²): writing `G̃ = Φ Φᵀ` with `Φ = C (W⁺)^{1/2}`
//! ([`nystrom_factor`]), the Woodbury identity gives
//!
//! ```text
//! α = (G̃ + λI)⁻¹ y = (y − Φ (λI + ΦᵀΦ)⁻¹ Φᵀ y) / λ
//! ```
//!
//! and the predictor collapses into the **landmark space**: for a query
//! point z the Nyström extension of its kernel row is
//! `ĝ(z, ·) = b(z)ᵀ W⁻¹ Cᵀ`, so
//!
//! ```text
//! f(z) = ĝ(z, ·) α = b(z)ᵀ β   with   β = W⁻¹ Cᵀ α ∈ R^k
//! ```
//!
//! — prediction touches only the k selected points (`b(z)_t =
//! k(z, x_{Λ(t)})`), which is what makes a stored model dataset-free:
//! an artifact's `Z_Λ` and kernel parameters are all it ever needs.

use crate::linalg::{pinv_psd, Cholesky, Mat};
use crate::nystrom::{nystrom_factor, NystromApprox};
use crate::Result;
use crate::bail;

/// A fitted Nyström KRR model: the ridge and the landmark-space dual
/// weights β (`f(z) = b(z)ᵀ β`).
///
/// Multi-output fits ([`fit_multi`](KrrModel::fit_multi)) share one
/// factorization across m label columns; `beta` then holds m weight
/// vectors back to back (output-major: output j is
/// `beta[j·k .. (j+1)·k]`). Single-output models have `outputs == 1` and
/// are bit-identical to what [`fit`](KrrModel::fit) has always produced.
#[derive(Clone, Debug)]
pub struct KrrModel {
    /// Ridge λ the model was fit with.
    pub lambda: f64,
    /// Landmark-space dual weights (`outputs` blocks of length k,
    /// selection order within each block).
    pub beta: Vec<f64>,
    /// Number of outputs m the model predicts per query point (≥ 1).
    pub outputs: usize,
    /// Root-mean-square error of the in-sample fit `C β` against y,
    /// pooled over all outputs.
    pub train_rmse: f64,
}

impl KrrModel {
    /// Fit dual weights from the rank-k factors in O(nk²). `y` must hold
    /// one label per data point; `lambda` must be > 0 (λ = 0 would ask
    /// for the pseudo-inverse of a rank-deficient G̃).
    pub fn fit(approx: &NystromApprox, y: &[f64], lambda: f64) -> Result<KrrModel> {
        Self::fit_multi(approx, std::slice::from_ref(&y.to_vec()), lambda)
    }

    /// Fit m outputs against one shared factorization: the O(nk²)
    /// Gram assembly `A = λI + ΦᵀΦ` and its Cholesky are computed once,
    /// and only the O(nk)-per-column Woodbury back-substitutions repeat —
    /// fitting m label columns costs barely more than fitting one.
    /// `ys` is output-major: `ys[j]` holds output j's label per data
    /// point. With m = 1 every operation matches
    /// [`fit`](KrrModel::fit)'s historical sequence, so single-output
    /// fits stay bit-identical.
    pub fn fit_multi(
        approx: &NystromApprox,
        ys: &[Vec<f64>],
        lambda: f64,
    ) -> Result<KrrModel> {
        let (n, k) = (approx.n(), approx.k());
        if ys.is_empty() {
            bail!("krr: at least one label column is required");
        }
        for (j, y) in ys.iter().enumerate() {
            if y.len() != n {
                bail!(
                    "krr: output {j} has {} labels for n = {n} data points",
                    y.len()
                );
            }
            if let Some(bad) = y.iter().find(|v| !v.is_finite()) {
                bail!("krr: output {j} label {bad} is not finite");
            }
        }
        if !(lambda.is_finite() && lambda > 0.0) {
            bail!("krr: ridge must be a finite number > 0 (got {lambda})");
        }
        let phi = nystrom_factor(approx); // n×k
        // A = λI + ΦᵀΦ (k×k, SPD for λ > 0; dedicated Gram kernel)
        let mut a = phi.syrk();
        for i in 0..k {
            *a.at_mut(i, i) += lambda;
        }
        // λ > 0 makes A PD in exact arithmetic; fall back to the
        // pseudo-inverse if rounding starved a pivot anyway. Either
        // factorization is computed once and reused for every output.
        let chol = Cholesky::new(&a);
        let pinv = if chol.is_none() { Some(pinv_psd(&a, 1e-14)) } else { None };
        let mut beta = Vec::with_capacity(k * ys.len());
        let mut sse = 0.0;
        for y in ys {
            // Φᵀ y / Cᵀ α below use Mat::t_matvec: the n×k factors are
            // the fit's dominant allocation, so nothing may materialize
            // their transpose
            let phity = phi.t_matvec(y);
            let z = match &chol {
                Some(ch) => ch.solve(&phity),
                None => pinv.as_ref().unwrap().matvec(&phity),
            };
            // α = (y − Φ z) / λ
            let phiz = phi.matvec(&z);
            let inv_l = 1.0 / lambda;
            let alpha: Vec<f64> =
                y.iter().zip(&phiz).map(|(yi, pi)| (yi - pi) * inv_l).collect();
            // β = W⁻¹ (Cᵀ α): the dual weights moved into landmark space
            let cta = approx.c.t_matvec(&alpha);
            let bj = approx.winv.matvec(&cta);
            // in-sample fit f(xᵢ) = G̃(i,·) α = C(i,·) β
            let fitted = approx.c.matvec(&bj);
            sse += fitted
                .iter()
                .zip(y)
                .map(|(f, yi)| (f - yi) * (f - yi))
                .sum::<f64>();
            beta.extend_from_slice(&bj);
        }
        Ok(KrrModel {
            lambda,
            beta,
            outputs: ys.len(),
            train_rmse: (sse / (n * ys.len()) as f64).sqrt(),
        })
    }

    /// The landmark count k the model was fit with.
    #[inline]
    pub fn k(&self) -> usize {
        self.beta.len() / self.outputs
    }

    /// Output j's weight vector (length k).
    #[inline]
    pub fn output_beta(&self, j: usize) -> &[f64] {
        let k = self.k();
        &self.beta[j * k..(j + 1) * k]
    }

    /// `f(z) = b(z)ᵀ β` for a precomputed landmark row
    /// ([`landmark_row`](super::landmark_row)). Single-output models
    /// only; multi-output callers use
    /// [`predict_block`](KrrModel::predict_block).
    #[inline]
    pub fn predict_row(&self, b: &[f64]) -> f64 {
        debug_assert_eq!(self.outputs, 1);
        crate::linalg::matrix::dot(b, &self.beta)
    }

    /// Batched prediction: one B×m value matrix from a B×k landmark
    /// block ([`landmark_block`](super::landmark_block)). Single-output
    /// models go through `Mat::matvec` — per row the same 4-way unrolled
    /// `dot` as [`predict_row`](KrrModel::predict_row), so a batch of B
    /// points is bit-identical to B single-point calls. Multi-output
    /// models run one blocked B×k × k×m matmul.
    pub fn predict_block(&self, b: &Mat) -> Mat {
        assert_eq!(b.cols, self.k(), "landmark block must be B×k");
        if self.outputs == 1 {
            Mat::from_vec(b.rows, 1, b.matvec(&self.beta))
        } else {
            // beta is output-major (m×k); the matmul wants k×m
            let mut bm = Mat::zeros(self.k(), self.outputs);
            for j in 0..self.outputs {
                let col = self.output_beta(j);
                for (t, &v) in col.iter().enumerate() {
                    *bm.at_mut(t, j) = v;
                }
            }
            b.matmul(&bm)
        }
    }

    /// β cast to f32 for the f32 serving path (cast once per request,
    /// not per point).
    pub fn beta_f32(&self) -> Vec<f32> {
        self.beta.iter().map(|&v| v as f32).collect()
    }

    /// Batched prediction in single precision end to end: `block` is a
    /// row-major B×k landmark block already cast to f32
    /// ([`landmark_block_f32`](super::landmark_block_f32)), `beta` the
    /// cached [`beta_f32`](KrrModel::beta_f32). Accumulation happens in
    /// f32 (that is the point of the mode — see the store's precision
    /// caveat), so values differ from the f64 path at single-precision
    /// scale. Returns B×m values, row-major.
    pub fn predict_block_f32(&self, block: &[f32], beta: &[f32]) -> Vec<f32> {
        let k = self.k();
        assert_eq!(beta.len(), self.beta.len(), "beta_f32 length");
        let rows = if k == 0 { 0 } else { block.len() / k };
        let mut out = Vec::with_capacity(rows * self.outputs);
        for i in 0..rows {
            let b = &block[i * k..(i + 1) * k];
            for j in 0..self.outputs {
                out.push(crate::linalg::matrix::dot_f32(b, &beta[j * k..(j + 1) * k]));
            }
        }
        out
    }

    /// In-sample predictions `C β` (one per training point) for output
    /// j — cheap to recompute, so they are not stored in the model.
    pub fn predict_in_sample_output(
        &self,
        approx: &NystromApprox,
        j: usize,
    ) -> Vec<f64> {
        approx.c.matvec(self.output_beta(j))
    }

    /// In-sample predictions `C β` for single-output models.
    pub fn predict_in_sample(&self, approx: &NystromApprox) -> Vec<f64> {
        self.predict_in_sample_output(approx, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::two_moons;
    use crate::data::Dataset;
    use crate::kernels::Gaussian;
    use crate::sampling::{assemble_from_indices, ImplicitOracle};
    use crate::tasks::landmark_row;

    fn full_rank_setup() -> (NystromApprox, Dataset, Gaussian, Vec<f64>) {
        let ds = two_moons(40, 0.05, 3);
        // a fairly local kernel keeps G well-conditioned, so the tiny-λ
        // fit below really can interpolate
        let kern = Gaussian::new(0.35);
        let approx = {
            let oracle = ImplicitOracle::new(&ds, &kern);
            assemble_from_indices(&oracle, (0..40).collect(), 0.0)
        };
        // a smooth target: y = sin(2x) + cos(y)
        let y: Vec<f64> = (0..40)
            .map(|i| {
                let p = ds.point(i);
                (2.0 * p[0]).sin() + p[1].cos()
            })
            .collect();
        (approx, ds, kern, y)
    }

    /// With all n columns sampled G̃ = G exactly, so a tiny ridge must
    /// interpolate the training labels almost exactly.
    #[test]
    fn near_interpolation_at_full_rank() {
        let (approx, _, _, y) = full_rank_setup();
        let m = KrrModel::fit(&approx, &y, 1e-8).unwrap();
        assert!(m.train_rmse < 1e-3, "train rmse {}", m.train_rmse);
    }

    /// The landmark-space predictor must agree with the dual-space
    /// in-sample fit: predicting at training point xᵢ via b(xᵢ) equals
    /// row i of C β, because b(xᵢ) is exactly C(i,·).
    #[test]
    fn landmark_prediction_consistent_with_in_sample() {
        let ds = two_moons(60, 0.05, 9);
        let kern = Gaussian::new(0.6);
        let oracle = ImplicitOracle::new(&ds, &kern);
        let idx: Vec<usize> = (0..60).step_by(2).collect();
        let approx = assemble_from_indices(&oracle, idx, 0.0);
        let y: Vec<f64> = (0..60).map(|i| (i % 2) as f64).collect();
        let m = KrrModel::fit(&approx, &y, 1e-3).unwrap();
        let fitted = m.predict_in_sample(&approx);
        let selected = ds.select(&approx.indices);
        for i in (0..60).step_by(7) {
            let b = landmark_row(&kern, &selected, ds.point(i)).unwrap();
            let by_row = m.predict_row(&b);
            assert!(
                (by_row - fitted[i]).abs() < 1e-8,
                "point {i}: {by_row} vs {fitted:?}"
            );
        }
        // predictions generalize: a held-out point near class-1 training
        // points predicts closer to 1 than to 0
        let z = ds.point(1).to_vec();
        let b = landmark_row(&kern, &selected, &z).unwrap();
        let f = m.predict_row(&b);
        assert!(f.is_finite());
    }

    #[test]
    fn ridge_regularizes() {
        let (approx, _, _, y) = full_rank_setup();
        let tight = KrrModel::fit(&approx, &y, 1e-8).unwrap();
        let loose = KrrModel::fit(&approx, &y, 10.0).unwrap();
        assert!(
            tight.train_rmse < loose.train_rmse,
            "{} !< {}",
            tight.train_rmse,
            loose.train_rmse
        );
        let norm = |b: &[f64]| b.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(norm(&loose.beta) < norm(&tight.beta));
    }

    #[test]
    fn bad_inputs_rejected() {
        let (approx, _, _, y) = full_rank_setup();
        assert!(KrrModel::fit(&approx, &y[..10], 1e-3).is_err());
        assert!(KrrModel::fit(&approx, &y, 0.0).is_err());
        assert!(KrrModel::fit(&approx, &y, f64::NAN).is_err());
        let mut bad = y.clone();
        bad[3] = f64::INFINITY;
        assert!(KrrModel::fit(&approx, &bad, 1e-3).is_err());
    }

    /// `fit` is the one-column case of `fit_multi`, bit for bit — the
    /// multi-output refactor must not move single-output numerics.
    #[test]
    fn fit_is_single_column_fit_multi() {
        let (approx, _, _, y) = full_rank_setup();
        let a = KrrModel::fit(&approx, &y, 1e-4).unwrap();
        let b = KrrModel::fit_multi(&approx, &[y.clone()], 1e-4).unwrap();
        assert_eq!(a.outputs, 1);
        assert_eq!(a.k(), approx.k());
        for (x, z) in a.beta.iter().zip(&b.beta) {
            assert_eq!(x.to_bits(), z.to_bits());
        }
        assert_eq!(a.train_rmse.to_bits(), b.train_rmse.to_bits());
        // empty label sets are rejected
        assert!(KrrModel::fit_multi(&approx, &[], 1e-4).is_err());
        // ragged columns are rejected
        assert!(
            KrrModel::fit_multi(&approx, &[y.clone(), y[..10].to_vec()], 1e-4)
                .is_err()
        );
    }

    /// Fits are deterministic functions of the factor bits: refitting
    /// gives bit-identical β.
    #[test]
    fn fit_is_deterministic() {
        let (approx, _, _, y) = full_rank_setup();
        let a = KrrModel::fit(&approx, &y, 1e-4).unwrap();
        let b = KrrModel::fit(&approx, &y, 1e-4).unwrap();
        assert_eq!(a.beta.len(), b.beta.len());
        for (x, z) in a.beta.iter().zip(&b.beta) {
            assert_eq!(x.to_bits(), z.to_bits());
        }
        assert_eq!(a.train_rmse.to_bits(), b.train_rmse.to_bits());
    }
}
