//! Production-style prediction serving, end to end: starts the server
//! in-process on an ephemeral port, fits a multi-output KRR model once
//! over the wire, then answers batched predictions on a single
//! kept-alive connection — the fit-once-predict-many pattern the task
//! endpoints are built for.
//!
//!     cargo run --release --example batch_serving
//!
//! What it demonstrates, in order:
//! - `ClientConn`: a persistent HTTP/1.1 keep-alive client, so the
//!   sweep below pays one TCP handshake total, not one per request.
//! - Multi-output KRR: `labels` as per-point rows fits m outputs
//!   against ONE shared factorization.
//! - Batched predict: a `predict` array of B points is served as one
//!   B×k kernel block + one blocked product (bit-identical to B
//!   single-point calls in f64).
//! - f32 serving mode: `"f32": true` per request, for throughput-first
//!   deployments that tolerate ~1e-6 relative error.
//! - `/metrics`: per-model predict-latency histograms and the
//!   batch-size distribution under the `"predict"` key.

use oasis::server::http::ClientConn;
use oasis::server::Server;
use oasis::util::json::Json;

fn exchange(conn: &mut ClientConn, method: &str, path: &str, body: &str) -> Json {
    let (status, raw) = conn.request(method, path, body).expect("http exchange");
    let json = Json::parse(&raw).expect("json body");
    assert!(status < 400, "{method} {path} → {status}: {json}");
    json
}

fn main() {
    let server = Server::bind("127.0.0.1:0").expect("bind");
    let addr = server.local_addr().expect("addr");
    let handle = std::thread::spawn(move || server.run().expect("serve"));
    println!("server listening on http://{addr}");

    // ONE connection for the whole lifecycle — every exchange below
    // reuses it (HTTP/1.1 keep-alive is the server default)
    let mut conn = ClientConn::connect(addr).expect("connect");

    let n = 400;
    exchange(
        &mut conn,
        "POST",
        "/sessions",
        &format!(
            r#"{{"name":"demo",
                 "dataset":{{"generator":"two-moons","n":{n},"seed":42}},
                 "max_cols":60,"init_cols":8,"seed":7}}"#
        ),
    );
    exchange(&mut conn, "POST", "/sessions/demo/step", r#"{"steps":40}"#);

    // fit a 2-output KRR model: labels as per-point [class, magnitude]
    // rows — one factorization is shared across both outputs
    let rows: Vec<String> = (0..n)
        .map(|i| format!("[{},{}]", (i % 2) as f64, i as f64 / n as f64))
        .collect();
    let fit = exchange(
        &mut conn,
        "POST",
        "/sessions/demo/task",
        &format!(r#"{{"task":"krr","ridge":1e-3,"labels":[{}]}}"#, rows.join(",")),
    );
    println!(
        "fitted krr: k = {} landmarks, {} outputs",
        fit.get("k").and_then(Json::as_usize).unwrap(),
        fit.get("outputs").and_then(Json::as_usize).unwrap_or(1),
    );

    // batched predict: B points in ONE request → one B×k kernel block,
    // one blocked product, one response (label-free → cached model)
    let batch = r#"{"predict":[[0.5,0.25],[-0.5,0.4],[1.2,-0.3],[0.0,0.9]]}"#;
    let rep = exchange(&mut conn, "POST", "/sessions/demo/task", batch);
    let preds = rep.get("predictions").and_then(Json::as_arr).unwrap();
    for (i, p) in preds.iter().enumerate() {
        println!("point {i}: f(z) = {p}");
    }

    // same batch in f32 serving mode: kernel row + dot products run in
    // f32 — compare against the f64 answers above
    let batch_f32 = r#"{"predict":[[0.5,0.25],[-0.5,0.4],[1.2,-0.3],[0.0,0.9]],"f32":true}"#;
    let rep32 = exchange(&mut conn, "POST", "/sessions/demo/task", batch_f32);
    let preds32 = rep32.get("predictions").and_then(Json::as_arr).unwrap();
    let drift = preds
        .iter()
        .zip(preds32)
        .flat_map(|(a, b)| {
            let a: Vec<f64> =
                a.as_arr().map(|v| v.iter().filter_map(Json::as_f64).collect()).unwrap_or_default();
            let b: Vec<f64> =
                b.as_arr().map(|v| v.iter().filter_map(Json::as_f64).collect()).unwrap_or_default();
            a.into_iter().zip(b).map(|(x, y)| (x - y).abs())
        })
        .fold(0.0f64, f64::max);
    println!("max |f64 − f32| across the batch: {drift:.2e}");

    // the predict section of /metrics: per-model latency histograms and
    // the batch-size distribution
    let metrics = exchange(&mut conn, "GET", "/metrics", "");
    if let Some(predict) = metrics.get("predict") {
        println!(
            "predict metrics: batch sizes seen = {}, mean batch = {}",
            predict
                .get("batch_size")
                .and_then(|b| b.get("count"))
                .and_then(Json::as_usize)
                .unwrap_or(0),
            predict
                .get("batch_size")
                .and_then(|b| b.get("mean"))
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
        );
    }

    exchange(&mut conn, "DELETE", "/sessions/demo", "");
    exchange(&mut conn, "POST", "/shutdown", "");
    handle.join().expect("server thread");
    println!("server stopped");
}
