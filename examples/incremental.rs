//! Incremental sampling: grow a Nyström approximation until it reaches a
//! target estimated error, snapshotting along the way — the serving-style
//! workflow the session API exists for (grow per request instead of
//! recomputing from scratch).
//!
//!     cargo run --release --example incremental -- [--n 4000] [--target 1e-2]

use oasis::data::generators::two_moons;
use oasis::kernels::Gaussian;
use oasis::nystrom::relative_frobenius_error;
use oasis::sampling::{
    oasis::Oasis, run_to_completion, ImplicitOracle, SamplerSession,
    StoppingCriterion, StoppingRule,
};
use oasis::util::args::Args;
use oasis::util::timing::fmt_secs;

fn main() -> oasis::Result<()> {
    let args = Args::from_env();
    let n = args.usize_or("n", 4_000);
    let target = args.f64_or("target", 1e-2);

    let ds = two_moons(n, 0.05, 42);
    let kernel = Gaussian::with_sigma_fraction(&ds, 0.05);
    let oracle = ImplicitOracle::new(&ds, &kernel);

    // one long-lived session; the initial budget only sizes the first
    // allocation — state grows on demand as the run is resumed
    let mut session = Oasis::new(64, 10, 1e-12, 7).session(&oracle)?;

    println!("growing until estimated relative error ≤ {target:.1e} (n = {n})\n");
    println!("{:>8} {:>14} {:>14} {:>12}", "columns", "estimate", "exact", "time");

    // grow in rounds of 64 columns, checking the error target between
    // rounds; a serving system would run one round per request instead
    let mut budget = 0usize;
    loop {
        budget += 64;
        let rule = StoppingRule::new()
            .with(StoppingCriterion::ErrorBelow(target))
            .with(StoppingCriterion::ColumnBudget(budget));
        let reason = run_to_completion(&mut session, &rule)?;
        let estimate = session.error_estimate().unwrap_or(f64::NAN);
        // exact error is O(n²·k) — affordable here, skipped in serving
        let snapshot = session.snapshot()?;
        let exact = relative_frobenius_error(&oracle, &snapshot);
        println!(
            "{:>8} {:>14.3e} {:>14.3e} {:>12}",
            session.k(),
            estimate,
            exact,
            fmt_secs(session.selection_secs()),
        );
        match reason {
            oasis::sampling::StopReason::BudgetReached => continue,
            other => {
                println!("\nstopped: {other:?} at k = {}", session.k());
                break;
            }
        }
    }
    Ok(())
}
