//! End-to-end system driver — the repository's headline validation run.
//!
//! Exercises the full stack on a real (synthetic-large) workload, the
//! paper's §V-D regime scaled to this container: a large Two Moons set is
//! sharded across oASIS-P worker threads, columns are selected and formed
//! without ever materializing G or even holding all shard state in one
//! place, and the result is compared against distributed uniform random
//! sampling on (i) sampled-entry approximation error, (ii) end-to-end
//! select+form wall time, (iii) bytes communicated.
//!
//!     cargo run --release --example end_to_end -- [--n 100000] [--cols 300] [--workers 8]
//!
//! The run is recorded in EXPERIMENTS.md (§End-to-end).

use oasis::coordinator::{run_oasis_p, OasisPConfig};
use oasis::data::generators::two_moons;
use oasis::kernels::{Gaussian, Kernel};
use oasis::linalg::pinv_psd;
use oasis::nystrom::{sampled_relative_error, NystromApprox};
use oasis::sampling::ImplicitOracle;
use oasis::util::args::Args;
use oasis::util::rng::Pcg64;
use oasis::util::timing::{fmt_bytes, fmt_secs, Stopwatch};
use std::sync::Arc;

fn main() -> oasis::Result<()> {
    let args = Args::from_env();
    let n = args.usize_or("n", 100_000);
    let cols = args.usize_or("cols", 300);
    let workers = args.usize_or("workers", 8);
    let seed = args.u64_or("seed", 7);

    println!("== end-to-end: oASIS-P vs distributed uniform random ==");
    println!("n={n} cols={cols} workers={workers} kernel=gaussian(σ=0.5·√3)\n");

    // paper §V-D-g uses σ = 0.5·√3 found on small trials
    let sigma = 0.5 * 3f64.sqrt();
    let ds = two_moons(n, 0.05, seed ^ 0xDA7A);
    let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(sigma));
    let gk = Gaussian::new(sigma);
    let oracle = ImplicitOracle::new(&ds, &gk);

    // --- oASIS-P ---
    let cfg = OasisPConfig::new(cols, 10.min(cols), workers)
        .with_seed(seed)
        .with_tol(1e-4); // the paper's §V-D-g error tolerance
    let (approx, report) = run_oasis_p(&ds, kernel, &cfg)?;
    let err = sampled_relative_error(&oracle, &approx, 100_000, seed ^ 0xE44);
    println!(
        "oASIS-P : k={:4}  error={:.3e}  select+form={}  comm: bcast {} / gather {}",
        approx.k(),
        err,
        fmt_secs(report.wall_secs),
        fmt_bytes(report.metrics.broadcast_bytes()),
        fmt_bytes(report.metrics.gather_bytes()),
    );

    // --- distributed uniform random baseline: select ℓ indices, form the
    //     same columns (threaded like the shards), then pay the W⁺ cost
    //     the paper highlights (random W is often rank-deficient) ---
    let sw = Stopwatch::start();
    let order = Pcg64::new(seed).sample_without_replacement(n, approx.k());
    let k = order.len();
    let mut c = oasis::linalg::Mat::zeros(n, k);
    {
        let data = &mut c.data;
        oasis::util::parallel::for_each_chunk_mut(
            data,
            k,
            workers,
            |range, chunk| {
                for (local, i) in range.clone().enumerate() {
                    let zi = ds.point(i);
                    for (t, &j) in order.iter().enumerate() {
                        chunk[local * k + t] = gk.eval(zi, ds.point(j));
                    }
                }
            },
        );
    }
    let w = c.select_rows(&order);
    let winv = pinv_psd(&w, 1e-12); // W⁺ — no iterative W⁻¹ available
    let rand_secs = sw.secs();
    let rand = NystromApprox { indices: order, c, winv, selection_secs: rand_secs };
    let err_r = sampled_relative_error(&oracle, &rand, 100_000, seed ^ 0xE44);
    println!(
        "Random  : k={:4}  error={:.3e}  select+form={}  (incl. {}×{} pseudo-inverse)",
        rand.k(),
        err_r,
        fmt_secs(rand_secs),
        k,
        k
    );

    println!(
        "\nheadline: oASIS-P reaches {:.1}% of random sampling's error at the same budget;\n\
         per-iteration communication is one {}-dim point broadcast ({} total for {} iters).",
        100.0 * err / err_r.max(1e-300),
        ds.dim(),
        fmt_bytes(report.metrics.broadcast_bytes()),
        report.metrics.iterations(),
    );
    Ok(())
}
