//! Persistence round trip: approximate a CSV-backed dataset, save the
//! factorization as a stored artifact, reload it, and answer
//! out-of-sample extension queries without the original dataset or
//! kernel oracle — the store-and-serve workflow behind
//! `oasis approximate --save` / `oasis query --load` and the server's
//! `POST /sessions/{name}/save` / `POST /artifacts/load`.
//!
//!     cargo run --release --example persist_and_query

use oasis::data::generators::two_moons;
use oasis::data::{loader, LoadLimits};
use oasis::kernels::{Gaussian, Kernel};
use oasis::nystrom::{Provenance, StoredArtifact};
use oasis::sampling::{
    oasis::Oasis, run_to_completion, ImplicitOracle, SamplerSession,
    StoppingRule,
};

fn main() -> oasis::Result<()> {
    let dir = std::env::temp_dir().join("oasis-persist-example");
    std::fs::create_dir_all(&dir)?;
    let csv = dir.join("moons.csv");
    let model = dir.join("moons.oasis");

    // 1. a dataset on disk (CSV here; the binary oasis-matrix format
    //    works the same and also loads per-worker shards)
    loader::save_csv(&csv, &two_moons(600, 0.05, 42))?;
    let ds = loader::load_dataset(&csv, &LoadLimits::unlimited())?;
    println!("loaded {} points of dim {} from {}", ds.n(), ds.dim(), csv.display());

    // 2. approximate it with a stepwise oASIS session
    let kernel = Gaussian::with_sigma_fraction(&ds, 0.05);
    let oracle = ImplicitOracle::new(&ds, &kernel);
    let mut session = Oasis::new(80, 10, 1e-12, 7).session(&oracle)?;
    run_to_completion(&mut session, &StoppingRule::budget(80))?;
    let approx = session.snapshot()?;
    let est = session.error_estimate();

    // 3. persist: indices, C, W⁻¹, the 80 selected points, and the
    //    resolved kernel parameters travel together in one checksummed file
    let artifact = StoredArtifact::from_parts(
        approx,
        &ds,
        &kernel,
        Provenance { source: format!("file:{}", csv.display()), method: "oASIS".into() },
        est,
    )?;
    let bytes = artifact.save(&model)?;
    println!("saved {} ({} bytes, k = {})", model.display(), bytes, artifact.k());

    // 4. reload — from here on the CSV could be deleted; queries only
    //    touch the k selected points stored inside the artifact
    let loaded = StoredArtifact::load(&model)?;
    let z = [0.5, 0.25];
    let weights = loaded.query_weights(&z)?;
    let values = loaded.extend(&weights, &[0, 100, 599])?;
    println!("ĝ(z, [0, 100, 599]) = {values:?}");

    // sanity: the stored path agrees with a live kernel evaluation path
    let b: Vec<f64> = loaded
        .approx
        .indices
        .iter()
        .map(|&j| kernel.eval(&z, ds.point(j)))
        .collect();
    let live = loaded.approx.extension_weights(&b);
    assert_eq!(weights, live, "stored artifact diverged from the live oracle");
    println!("stored-vs-live extension weights: bit-identical");

    std::fs::remove_file(&csv).ok();
    std::fs::remove_file(&model).ok();
    Ok(())
}
