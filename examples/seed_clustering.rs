//! SEED clustering (paper §II-E / [30]): oASIS selects a dictionary of
//! representative data points, OMP codes every point sparsely over it,
//! and spectral clustering on the code affinity recovers the clusters —
//! all without ever forming the n×n kernel/Gram matrix.
//!
//!     cargo run --release --example seed_clustering

use oasis::data::generators::union_of_subspaces;
use oasis::seed::cluster::{permutation_accuracy, spectral_cluster};
use oasis::seed::{css_projection_error, Seed, SeedConfig};

fn main() -> oasis::Result<()> {
    // 4 random 3-dimensional subspaces in R^30 — the sparse-subspace-
    // clustering workload SEED targets ([30])
    let (n, k_true) = (600, 4);
    let ds = union_of_subspaces(n, 30, k_true, 3, 0.01, 11);
    let truth: Vec<usize> = (0..n).map(|i| i % k_true).collect();

    let cfg = SeedConfig { dict_size: 24, sparsity: 3, tol_sq: 1e-12, seed: 7 };
    let seed = Seed::decompose(&ds, &cfg)?;
    println!(
        "SEED: dictionary {} points, per-point sparsity ≤ {}, \
         ‖Z − Z_Λ X‖_F/‖Z‖_F = {:.3e}",
        seed.dictionary.len(),
        cfg.sparsity,
        seed.relative_error
    );
    println!(
        "Eq. 7 projection error of the oASIS dictionary: {:.3e}",
        css_projection_error(&ds, &seed.dictionary)
    );

    let labels = spectral_cluster(&seed.affinity(), k_true, 3);
    let acc = permutation_accuracy(&labels, &truth, k_true);
    println!("spectral clustering on SEED affinity: {:.1}% accuracy", 100.0 * acc);
    Ok(())
}
