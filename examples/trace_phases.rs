//! Trace where an oASIS run spends its time: enable the process-global
//! recorder, run a stepwise session, then print the per-phase cost
//! breakdown (score scan vs column fetch vs factor update) and write a
//! Chrome `trace_event` file you can open at chrome://tracing or
//! <https://ui.perfetto.dev>.
//!
//!     cargo run --release --example trace_phases
//!
//! The same recorder drives `oasis approximate --trace out.json`; this
//! example is the library-level version of that flag.

use oasis::data::generators::two_moons;
use oasis::kernels::Gaussian;
use oasis::nystrom::relative_frobenius_error;
use oasis::sampling::{
    oasis::Oasis, run_to_completion, ImplicitOracle, SamplerSession,
    StoppingRule,
};
use oasis::util::timing::fmt_secs;
use oasis::{obs, util::fsio};

fn main() -> oasis::Result<()> {
    let ds = two_moons(2_000, 0.05, 42);
    let kernel = Gaussian::with_sigma_fraction(&ds, 0.05);
    let oracle = ImplicitOracle::new(&ds, &kernel);

    // 1. switch the recorder on — every span/event below lands in a
    //    bounded ring buffer (drop-oldest, so a long run can't OOM)
    obs::trace::enable();

    // 2. an ordinary session run: the sampler's hot path is already
    //    instrumented (score_scan, column_fetch, factor_update), so
    //    nothing here mentions tracing
    let mut session = Oasis::new(400, 10, 1e-12, 7).session(&oracle)?;
    run_to_completion(&mut session, &StoppingRule::budget(400))?;
    let approx = session.snapshot()?;
    println!(
        "selected {} columns  error {:.3e}  in {}\n",
        approx.k(),
        relative_frobenius_error(&oracle, &approx),
        fmt_secs(approx.selection_secs),
    );

    // 3. drain the buffer (this also detaches it from the hot paths)
    obs::trace::disable();
    let trace = obs::trace::drain();

    // 4. per-phase rollup: each span name becomes a latency histogram
    //    with count / total / p50 / p99 / max, sorted by total time
    println!(
        "{:<16} {:>7} {:>10} {:>10} {:>10} {:>10}",
        "phase", "count", "total", "p50", "p99", "max"
    );
    for p in trace.phase_summary() {
        println!(
            "{:<16} {:>7} {:>10} {:>10} {:>10} {:>10}",
            p.name,
            p.hist.count(),
            fmt_secs(p.hist.sum()),
            fmt_secs(p.hist.quantile(0.50)),
            fmt_secs(p.hist.quantile(0.99)),
            fmt_secs(p.hist.max()),
        );
    }

    // 5. Chrome trace_event export — open it in a trace viewer to see
    //    the spans on a timeline
    let path = std::path::Path::new("trace_phases.json");
    let json = trace.to_chrome_json().to_string();
    fsio::write_atomic(path, json.as_bytes())?;
    println!(
        "\n{} events ({} dropped) written to {}",
        trace.events.len(),
        trace.dropped,
        path.display()
    );
    Ok(())
}
