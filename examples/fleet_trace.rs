//! Trace a whole oASIS-P fleet on one timeline: run a real TCP leader
//! with worker threads standing in for worker processes, collect the
//! spans every worker recorded locally (shipped leader-ward at run
//! end), merge them with the leader's own trace, and write one Chrome
//! `trace_event` file with a separate process track per worker — open
//! it at chrome://tracing or <https://ui.perfetto.dev>.
//!
//!     cargo run --release --example fleet_trace
//!
//! The same machinery drives `oasis parallel --listen … --trace out.json`
//! (with `oasis worker --join …` processes on other nodes); this example
//! is the library-level version of that flag.

use oasis::coordinator::{
    run_worker, OasisPConfig, OasisPSession, ShardPlan, TcpTransport,
    WorkerRunOpts,
};
use oasis::data::generators::two_moons;
use oasis::data::{loader, LoadLimits};
use oasis::kernels::{Gaussian, Kernel};
use oasis::obs::trace;
use oasis::sampling::{run_to_completion, StoppingRule};
use oasis::util::fsio;
use std::sync::Arc;

fn main() -> oasis::Result<()> {
    // TCP workers shard-read the dataset themselves, so it must live in
    // a file: write a small generated dataset to a temp directory
    let dir = std::env::temp_dir()
        .join("oasis-fleet-trace")
        .join(format!("r{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let n = 400;
    let ds = two_moons(n, 0.05, 42);
    let path = dir.join("points.mat");
    loader::save_matrix(&path, &ds)?;

    // 1. switch the process-global recorder on BEFORE the fleet starts:
    //    the leader's Assign handshake tells each worker whether to
    //    record, so a disabled leader means untraced workers
    trace::enable();

    // 2. a real localhost fleet: the leader listens, three `run_worker`
    //    threads join exactly like `oasis worker --join ADDR` processes
    //    would, each recording its own spans locally
    let transport = TcpTransport::bind("127.0.0.1:0")?;
    let addr = transport.local_addr()?.to_string();
    let workers: Vec<_> = (0..3)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                run_worker(&addr, WorkerRunOpts::default()).unwrap()
            })
        })
        .collect();

    let kernel: Arc<dyn Kernel + Send + Sync> = Arc::new(Gaussian::new(0.6));
    let mut cfg = OasisPConfig::new(40, 5, 3).with_seed(7);
    cfg.timeout = std::time::Duration::from_secs(30);
    let plan = ShardPlan::File {
        path: path.clone(),
        n,
        limits: LoadLimits::unlimited(),
    };
    let mut session =
        OasisPSession::start_with_transport(Box::new(transport), plan, kernel, cfg)?;
    run_to_completion(&mut session, &StoppingRule::budget(40))?;

    // 3. finish_run drains every worker's trace ring over the wire and
    //    hands the per-worker tracks back in the report
    let (approx, report) = session.finish_run()?;
    for w in workers {
        w.join().expect("worker thread");
    }
    println!(
        "fleet of {} workers selected {} columns",
        report.workers,
        approx.k()
    );

    // 4. the leader's own spans (gather/arbitrate/broadcast rounds) come
    //    from the local recorder; pid 1 is the leader track by convention
    trace::disable();
    let leader = trace::drain();
    let n_leader = leader.events.len();
    let mut tracks = vec![leader.into_track(1, "leader")];
    tracks.extend(report.worker_traces);

    // 5. one merged Chrome trace: every track renders as its own process
    //    row, so the timeline shows leader rounds above per-worker work
    let out = std::path::Path::new("fleet_trace.json");
    let json = trace::merged_chrome_json(&tracks).to_string();
    fsio::write_atomic(out, json.as_bytes())?;
    println!(
        "{} leader events + {} worker track(s) written to {}",
        n_leader,
        tracks.len() - 1,
        out.display()
    );
    for t in &tracks[1..] {
        println!("  pid {:>2}  {:<10} {:>5} events", t.pid, t.label, t.events.len());
    }

    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
