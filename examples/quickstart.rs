//! Quickstart: approximate a Gaussian kernel matrix of the Two Moons
//! dataset with a stepwise oASIS session and compare against uniform
//! random sampling at the same budget.
//!
//!     cargo run --release --example quickstart

use oasis::data::generators::two_moons;
use oasis::kernels::Gaussian;
use oasis::nystrom::relative_frobenius_error;
use oasis::sampling::{
    oasis::Oasis, run_to_completion, uniform::Uniform, ColumnSampler,
    ImplicitOracle, SamplerSession, StoppingCriterion, StoppingRule,
};
use oasis::util::timing::fmt_secs;

fn main() -> oasis::Result<()> {
    // 1. data + kernel (σ = 5% of max pairwise distance, as in the paper)
    let ds = two_moons(2_000, 0.05, 42);
    let kernel = Gaussian::with_sigma_fraction(&ds, 0.05);

    // 2. a column oracle — kernel columns are computed on demand;
    //    the full 2000×2000 matrix is never formed
    let oracle = ImplicitOracle::new(&ds, &kernel);

    // 3. open an oASIS session and grow it to 450 columns; the stopping
    //    policy lives in the rule, not the sampler, so the same session
    //    could stop on an error target or a deadline instead
    let mut session = Oasis::new(450, 10, 1e-12, 7).session(&oracle)?;
    let rule = StoppingRule::new().with(StoppingCriterion::ColumnBudget(450));
    let reason = run_to_completion(&mut session, &rule)?;
    let approx = session.snapshot()?;
    let err = relative_frobenius_error(&oracle, &approx);
    println!(
        "oASIS : {} columns  error {:.3e}  selected in {}  ({reason:?})",
        approx.k(),
        err,
        fmt_secs(approx.selection_secs)
    );

    // 4. same budget, uniform random (one-shot API — still available)
    let rand = Uniform::new(450, 7).sample(&oracle)?;
    let err_r = relative_frobenius_error(&oracle, &rand);
    println!(
        "Random: {} columns  error {:.3e}  selected in {}",
        rand.k(),
        err_r,
        fmt_secs(rand.selection_secs)
    );

    println!(
        "\noASIS is {:.0}x more accurate at the same column budget.",
        err_r / err.max(1e-300)
    );

    // 5. sessions resume: another 150 columns on top of the same state
    run_to_completion(&mut session, &StoppingRule::budget(600))?;
    let more = session.snapshot()?;
    let err_more = relative_frobenius_error(&oracle, &more);
    println!(
        "resumed to {} columns  error {:.3e} (no recompute of the first 450)",
        more.k(),
        err_more
    );
    Ok(())
}
