//! Quickstart: approximate a Gaussian kernel matrix of the Two Moons
//! dataset with oASIS and compare against uniform random sampling.
//!
//!     cargo run --release --example quickstart

use oasis::data::generators::two_moons;
use oasis::kernels::Gaussian;
use oasis::nystrom::relative_frobenius_error;
use oasis::sampling::{oasis::Oasis, uniform::Uniform, ColumnSampler, ImplicitOracle};
use oasis::util::timing::fmt_secs;

fn main() -> oasis::Result<()> {
    // 1. data + kernel (σ = 5% of max pairwise distance, as in the paper)
    let ds = two_moons(2_000, 0.05, 42);
    let kernel = Gaussian::with_sigma_fraction(&ds, 0.05);

    // 2. a column oracle — kernel columns are computed on demand;
    //    the full 2000×2000 matrix is never formed
    let oracle = ImplicitOracle::new(&ds, &kernel);

    // 3. sample 450 columns adaptively with oASIS
    let approx = Oasis::new(450, 10, 1e-12, 7).sample(&oracle)?;
    let err = relative_frobenius_error(&oracle, &approx);
    println!(
        "oASIS : {} columns  error {:.3e}  selected in {}",
        approx.k(),
        err,
        fmt_secs(approx.selection_secs)
    );

    // 4. same budget, uniform random
    let rand = Uniform::new(450, 7).sample(&oracle)?;
    let err_r = relative_frobenius_error(&oracle, &rand);
    println!(
        "Random: {} columns  error {:.3e}  selected in {}",
        rand.k(),
        err_r,
        fmt_secs(rand.selection_secs)
    );

    println!(
        "\noASIS is {:.0}x more accurate at the same column budget.",
        err_r / err.max(1e-300)
    );
    Ok(())
}
