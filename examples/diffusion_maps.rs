//! Nonlinear dimensionality reduction with diffusion maps through the
//! Nyström approximation (paper §II-B / [2]): the downstream application
//! the paper motivates — compute a low-dimensional embedding of a manifold
//! dataset from a *subset* of kernel columns, never taking the O(n³) SVD
//! of the full matrix.
//!
//!     cargo run --release --example diffusion_maps

use oasis::data::generators::two_moons;
use oasis::kernels::{diffusion_normalize, kernel_matrix, Gaussian};
use oasis::nystrom::embedding::diffusion_coordinates;
use oasis::sampling::{oasis::Oasis, ColumnSampler, ExplicitOracle};

fn main() -> oasis::Result<()> {
    let n = 1_000;
    let ds = two_moons(n, 0.04, 11);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.05);

    // diffusion-normalized kernel matrix M = D^{-1/2} N D^{-1/2}
    let mut m = kernel_matrix(&ds, &kern);
    diffusion_normalize(&mut m);
    let oracle = ExplicitOracle::new(&m);

    // Nyström via oASIS with ℓ ≪ n columns
    let l = 120;
    let approx = Oasis::new(l, 10, 1e-12, 3).sample(&oracle)?;
    println!(
        "sampled {}/{} columns in {:.2}s",
        approx.k(),
        n,
        approx.selection_secs
    );

    // 2-D diffusion coordinates from the approximate eigenvectors
    let coords = diffusion_coordinates(&approx, 2, 1.0);

    // how well do the moons separate? (generator alternates labels)
    let mut acc = [[0usize; 2]; 2];
    for i in 0..n {
        let side = usize::from(coords.at(i, 0) > 0.0);
        acc[i % 2][side] += 1;
    }
    let correct = acc[0][0].max(acc[0][1]) + acc[1][0].max(acc[1][1]);
    println!(
        "first diffusion coordinate separates the moons: {:.1}% purity",
        100.0 * correct as f64 / n as f64
    );

    // print a small sample of the embedding for plotting
    println!("\n  i  moon     ψ₁          ψ₂");
    for i in (0..n).step_by(100) {
        println!(
            "{:4}  {}  {:>+10.4e}  {:>+10.4e}",
            i,
            i % 2,
            coords.at(i, 0),
            coords.at(i, 1)
        );
    }
    Ok(())
}
