//! The downstream-task pipeline end to end: **sample → save → fit →
//! predict**. A stepwise oASIS session approximates a labeled dataset,
//! the factorization is persisted, a Nyström KRR model is fit from the
//! artifact's rank-k factors (O(nk²), never forming the n×n kernel
//! matrix), attached to the artifact, and finally reloaded in a
//! "serving process" that predicts for unseen points with **neither the
//! dataset nor the labels** — only the k selected points stored in the
//! artifact. The same flow runs as `oasis task --task krr` on the CLI
//! and `POST /artifacts/{name}/task` on the server.
//!
//!     cargo run --release --example krr_pipeline

use oasis::data::generators::two_moons;
use oasis::kernels::Gaussian;
use oasis::nystrom::{Provenance, StoredArtifact};
use oasis::sampling::{
    oasis::Oasis, run_to_completion, ImplicitOracle, SamplerSession,
    StoppingRule,
};
use oasis::tasks::{FittedTask, TaskConfig, TaskKind, TaskPrediction};

fn main() -> oasis::Result<()> {
    let dir = std::env::temp_dir().join("oasis-krr-example");
    std::fs::create_dir_all(&dir)?;
    let model_path = dir.join("moons-krr.oasis");

    // 1. SAMPLE — a labeled dataset (moon membership alternates with the
    //    index in this generator) approximated by a stepwise session
    let n = 800;
    let ds = two_moons(n, 0.05, 42);
    let labels: Vec<f64> = (0..n).map(|i| (i % 2) as f64).collect();
    let kernel = Gaussian::with_sigma_fraction(&ds, 0.1);
    let oracle = ImplicitOracle::new(&ds, &kernel);
    let mut session = Oasis::new(90, 10, 1e-12, 7).session(&oracle)?;
    run_to_completion(&mut session, &StoppingRule::budget(90))?;
    let approx = session.snapshot()?;
    println!("sampled k = {} of n = {n} columns", approx.k());

    // 2. FIT — Nyström KRR dual weights from the rank-k factors; the
    //    model lives entirely in the k-dimensional landmark space
    let mut cfg = TaskConfig::new(TaskKind::Krr);
    cfg.ridge = 1e-3;
    cfg.labels = Some(labels);
    let fit = FittedTask::fit(&approx, &cfg)?;
    if let FittedTask::Krr(m) = &fit.model {
        println!("fit krr: ridge = {:e}, train rmse = {:.3e}", m.lambda, m.train_rmse);
    }

    // 3. SAVE — factors, selected points, kernel params, and the fitted
    //    model travel together in one checksummed artifact
    let artifact = StoredArtifact::from_parts(
        approx,
        &ds,
        &kernel,
        Provenance { source: "generator:two-moons".into(), method: "oASIS".into() },
        session.error_estimate(),
    )?
    .with_task(fit.model)?;
    let bytes = artifact.save(&model_path)?;
    println!("saved {} ({bytes} bytes, incl. task section)", model_path.display());

    // 4. PREDICT — a fresh process: no dataset, no labels, no oracle.
    //    Each prediction evaluates the kernel against the k stored
    //    selected points only: f(z) = b(z)ᵀ β.
    let loaded = StoredArtifact::load(&model_path)?;
    let stored_model = loaded.task.as_ref().expect("artifact carries the model");
    let stored_kernel = loaded.kernel.build();
    let queries =
        vec![vec![0.1, 0.4], vec![1.0, -0.45], vec![-0.9, 0.3], vec![1.9, 0.2]];
    let preds = stored_model.predict(
        &*stored_kernel,
        &loaded.selected_points,
        &queries,
    )?;
    if let TaskPrediction::Values(vs) = &preds {
        for (z, f) in queries.iter().zip(vs) {
            let class = if *f > 0.5 { 1 } else { 0 };
            println!("f({z:?}) = {f:+.4}  → moon {class}");
        }
    }

    std::fs::remove_file(&model_path).ok();
    Ok(())
}
