//! Figure 5 reproduction: exact recovery of a rank-3 Gram matrix.
//!
//! A 2-D Gaussian cloud at (0,0) plus a 3-D Gaussian cloud at (0,0,1)
//! give a Gram matrix G = ZᵀZ of rank 3. oASIS selects a linearly
//! independent column each step (Lemma 1) and recovers G exactly in
//! 3 columns (Theorem 1); uniform random sampling picks redundant columns
//! and stalls at a rank-deficient approximation.
//!
//!     cargo run --release --example exact_recovery

use oasis::data::generators::gauss_2d_plus_3d;
use oasis::kernels::{kernel_matrix, Linear};
use oasis::linalg::eig::psd_rank;
use oasis::sampling::{
    assemble_from_indices, oasis::Oasis, uniform::Uniform, ExplicitOracle,
};

fn main() -> oasis::Result<()> {
    let ds = gauss_2d_plus_3d(100, 100, 5);
    let g = kernel_matrix(&ds, &Linear);
    let oracle = ExplicitOracle::new(&g);
    let gnorm = g.fro_norm();

    println!("rank(G) = {}", psd_rank(&g, 1e-9));
    println!("\n{:28} {:>3} {:>12} {:>6}", "method", "k", "error", "rank");

    // oASIS with a generous budget: terminates by tolerance at rank
    let (_, trace) = Oasis::new(8, 1, 1e-9, 1).sample_traced(&oracle)?;
    for k in 1..=trace.order.len() {
        let approx = assemble_from_indices(&oracle, trace.order[..k].to_vec(), 0.0);
        let err = approx.reconstruct().fro_dist(&g) / gnorm;
        let rank = psd_rank(&approx.reconstruct(), 1e-9);
        println!("{:28} {:>3} {:>12.3e} {:>6}", "oASIS", k, err, rank);
    }

    // five random trials (paper shows their redundant selections)
    for trial in 0..5 {
        let (_, tr) = Uniform::new(8, 100 + trial).sample_traced(&oracle)?;
        for k in [1usize, 2, 3, 5, 8] {
            let approx = assemble_from_indices(&oracle, tr.order[..k].to_vec(), 0.0);
            let err = approx.reconstruct().fro_dist(&g) / gnorm;
            let rank = psd_rank(&approx.reconstruct(), 1e-9);
            println!(
                "{:28} {:>3} {:>12.3e} {:>6}",
                format!("Random (trial {})", trial + 1),
                k,
                err,
                rank
            );
        }
    }
    println!(
        "\noASIS terminates at exact recovery after 3 columns; random \
         sampling keeps choosing columns inside the span it already has."
    );
    Ok(())
}
