//! `oasis serve` lifecycle, end to end: starts the server in-process on
//! an ephemeral port, then drives it over a real socket exactly the way
//! an external client would — create a session, grow it in batches while
//! watching the error estimate, snapshot mid-run, answer out-of-sample
//! queries against the live snapshot, read `/metrics`, finish, shut down.
//!
//!     cargo run --release --example serve_client
//!
//! Against an already-running server, point your own client at the same
//! endpoints; the wire format is documented in the `oasis::server` docs.

use oasis::server::http::client_request;
use oasis::server::Server;
use oasis::util::json::Json;
use std::net::SocketAddr;

/// One HTTP exchange on a fresh connection (the shared one-shot client
/// from `oasis::server::http`; real clients would keep the connection
/// alive).
fn request(addr: SocketAddr, method: &str, path: &str, body: &str) -> Json {
    let (status, raw) =
        client_request(addr, method, path, body).expect("http exchange");
    let json = Json::parse(&raw).expect("json body");
    assert!(status < 400, "{method} {path} → {status}: {json}");
    json
}

fn main() {
    // serve in-process on an ephemeral port (a real deployment runs
    // `oasis serve --port 7437` instead)
    let server = Server::bind("127.0.0.1:0").expect("bind");
    let addr = server.local_addr().expect("addr");
    let handle = std::thread::spawn(move || server.run().expect("serve"));
    println!("server listening on http://{addr}");

    // create a session: two-moons, Gaussian kernel, oASIS
    let created = request(
        addr,
        "POST",
        "/sessions",
        r#"{"name":"demo",
            "dataset":{"generator":"two-moons","n":2000,"seed":42},
            "kernel":{"type":"gaussian","sigma_fraction":0.05},
            "method":"oasis","max_cols":450,"init_cols":10,"seed":7}"#,
    );
    println!(
        "created session '{}' (n = {}, k = {})",
        created.get("name").and_then(Json::as_str).unwrap(),
        created.get("n").and_then(Json::as_usize).unwrap(),
        created.get("k").and_then(Json::as_usize).unwrap(),
    );

    // grow it in batches, watching the error estimate fall
    for batch in 0..4 {
        let rep = request(
            addr,
            "POST",
            "/sessions/demo/step",
            r#"{"steps":50,"target_err":1e-3}"#,
        );
        println!(
            "batch {batch}: k = {} (+{}) estimate = {:.3e} in {:.1} ms{}",
            rep.get("k").and_then(Json::as_usize).unwrap(),
            rep.get("stepped").and_then(Json::as_usize).unwrap(),
            rep.get("error_estimate").and_then(Json::as_f64).unwrap_or(f64::NAN),
            rep.get("secs").and_then(Json::as_f64).unwrap() * 1e3,
            rep.get("stop")
                .and_then(Json::as_str)
                .map(|s| format!(" [stopped: {s}]"))
                .unwrap_or_default(),
        );
        if rep.get("stop").is_some() {
            break;
        }
    }

    // snapshot the live factors (indices only here; add ?factors=1 for C
    // and W⁻¹)
    let snap = request(addr, "GET", "/sessions/demo/snapshot", "");
    println!(
        "snapshot: k = {} columns, first indices {:?}…",
        snap.get("k").and_then(Json::as_usize).unwrap(),
        snap.get("indices")
            .and_then(Json::as_arr)
            .map(|a| a.iter().take(5).filter_map(Json::as_usize).collect::<Vec<_>>())
            .unwrap_or_default(),
    );

    // out-of-sample extension query against the live snapshot
    let q = request(
        addr,
        "POST",
        "/sessions/demo/query",
        r#"{"points":[[0.5,0.25],[-0.5,0.4]],"targets":[0,1,2]}"#,
    );
    let results = q.get("results").and_then(Json::as_arr).unwrap();
    for (i, r) in results.iter().enumerate() {
        let kernel: Vec<f64> = r
            .get("kernel")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_f64).collect())
            .unwrap_or_default();
        println!("query point {i}: ĝ(z, [0,1,2]) = {kernel:?}");
    }

    // server-wide metrics
    let metrics = request(addr, "GET", "/metrics", "");
    println!(
        "metrics: {} requests, {} live session(s)",
        metrics
            .get("server")
            .and_then(|s| s.get("requests"))
            .and_then(Json::as_usize)
            .unwrap(),
        metrics.get("sessions").and_then(Json::as_arr).unwrap().len(),
    );

    // finish (final factors + eviction), then shut the server down
    let fin = request(addr, "POST", "/sessions/demo/finish", "");
    println!(
        "finished: final k = {}",
        fin.get("k").and_then(Json::as_usize).unwrap()
    );
    request(addr, "POST", "/shutdown", "");
    handle.join().expect("server thread");
    println!("server stopped");
}
