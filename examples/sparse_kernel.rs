//! Sparse-kernel workflow (paper §V-E): k-NN-truncated similarity
//! matrices. oASIS only ever touches the sampled columns, so sparsity is
//! preserved end to end, whereas residual-based greedy methods (Farahat)
//! densify an n×n residual.
//!
//!     cargo run --release --example sparse_kernel

use oasis::data::generators::two_moons;
use oasis::kernels::Gaussian;
use oasis::nystrom::relative_frobenius_error;
use oasis::sampling::{
    farahat::Farahat, oasis::Oasis, uniform::Uniform, ColumnSampler,
    ImplicitOracle, SparseKnnOracle,
};
use oasis::util::timing::fmt_bytes;

fn main() -> oasis::Result<()> {
    let n = 3_000;
    let knn = 48;
    let ds = two_moons(n, 0.05, 17);
    let kern = Gaussian::with_sigma_fraction(&ds, 0.08);

    println!("building {n}-point {knn}-NN sparse kernel oracle...");
    let sparse = SparseKnnOracle::build(&ds, &kern, knn);
    println!(
        "density {:.2}% — sparse storage ≈ {}, dense would be {}",
        100.0 * sparse.density(),
        fmt_bytes((sparse.density() * (n * n) as f64 * 12.0) as u64),
        fmt_bytes((n * n * 8) as u64),
    );

    let l = 250;
    let approx = Oasis::new(l, 10, 1e-12, 5).sample(&sparse)?;
    let err = relative_frobenius_error(&sparse, &approx);
    println!(
        "\noASIS on sparse oracle : k={} error={:.3e} time={:.2}s  \
         (state: ℓ×n = {})",
        approx.k(),
        err,
        approx.selection_secs,
        fmt_bytes((l * n * 8) as u64),
    );

    // uniform random at the same budget, for context (k-NN-truncated
    // kernels are intrinsically high-rank, so absolute errors are large
    // for every method; the adaptive selection still wins)
    let rand = Uniform::new(l, 5).sample(&sparse)?;
    let err_r = relative_frobenius_error(&sparse, &rand);
    println!(
        "Random                 : k={} error={:.3e} time={:.2}s",
        rand.k(),
        err_r,
        rand.selection_secs,
    );

    // contrast: Farahat must materialize the dense n×n residual. NOTE:
    // k-NN truncation breaks positive semidefiniteness, which greedy
    // residual deflation is sensitive to — its error can even diverge —
    // while oASIS only ever evaluates Schur complements of sampled
    // columns. We report Farahat's cost; treat its error as illustrative.
    let far = Farahat::new(l).sample(&sparse)?;
    let err_f = relative_frobenius_error(&sparse, &far);
    println!(
        "Farahat (dense resid.) : k={} error={:.3e} time={:.2}s  \
         (state: n×n = {})",
        far.k(),
        err_f,
        far.selection_secs,
        fmt_bytes((n * n * 8) as u64),
    );

    // the dense-kernel error for context
    let dense = ImplicitOracle::new(&ds, &kern);
    let err_dense = relative_frobenius_error(&dense, &approx);
    println!(
        "\n(the same Λ applied to the un-truncated kernel: error {err_dense:.3e})"
    );
    Ok(())
}
