#!/usr/bin/env python3
"""Benchmark regression gate for the blocked linalg kernels.

Usage: bench_gate.py BENCH_main.json BENCH_ci.json

Compares the gated ``micro`` entries of the current bench run (the
bench-smoke job's BENCH_ci.json artifact) against the committed baseline
(BENCH_main.json). The gated entries are the paired kernel benches
emitted by ``cargo bench --bench perf`` — every micro entry carrying a
``speedup`` field, which is ``naive_median / kernel_median`` at the same
shape on the same machine. Ratios are dimensionless, so a slow or fast
CI runner cancels out of the comparison; absolute medians are printed
for information but never gated on.

Gate rule: for each required kernel (matmul, syrk, fused_step,
columns_into) the current speedup must be at least ``baseline / 1.25``
— i.e. a >25% relative regression fails the job. The 25% tolerance
absorbs runner-to-runner variance in cache sizes and core counts
(observed quick-size jitter is well under that); shrink it only after
collecting enough artifacts to justify a tighter band.

A required kernel missing from the current run fails the gate (a
renamed or deleted bench must update this script, BENCH_main.json, and
perf.rs together). Extra micro entries are listed informationally.

Updating the baseline after an intentional kernel change: download the
PR's ``bench-ci`` artifact and commit its BENCH_ci.json as
BENCH_main.json in the same PR (see rust/benches/perf.rs header docs).
"""

import json
import sys

TOLERANCE = 1.25  # fail below baseline_speedup / TOLERANCE
REQUIRED = ("matmul", "syrk", "fused_step", "columns_into")


def load_gated(path):
    with open(path) as f:
        doc = json.load(f)
    return {
        e["name"]: e for e in doc.get("micro", []) if "speedup" in e
    }


def fmt_ms(entry, key):
    v = entry.get(key)
    return f"{v:8.3f}" if isinstance(v, (int, float)) else "       —"


def main():
    if len(sys.argv) != 3:
        sys.exit(f"usage: {sys.argv[0]} BENCH_main.json BENCH_ci.json")
    base = load_gated(sys.argv[1])
    curr = load_gated(sys.argv[2])

    print(f"bench gate: speedup ratios, tolerance ×{TOLERANCE}")
    print(
        f"{'kernel':<14} {'base':>7} {'floor':>7} {'current':>8} "
        f"{'naive_ms':>9} {'kernel_ms':>10}  verdict"
    )
    failures = []
    for name in REQUIRED:
        b = base.get(name)
        c = curr.get(name)
        if c is None:
            failures.append(f"{name}: missing from current run")
            print(f"{name:<14} {'—':>7} {'—':>7} {'—':>8} {'—':>9} {'—':>10}  MISSING")
            continue
        cur_speedup = c["speedup"]
        if b is None:
            # a brand-new pair gates only on being present; it enters the
            # baseline at the next BENCH_main.json refresh
            print(
                f"{name:<14} {'—':>7} {'—':>7} {cur_speedup:8.2f} "
                f"{fmt_ms(c, 'naive_median_ms'):>9} {fmt_ms(c, 'median_ms'):>10}  new (no baseline)"
            )
            continue
        floor = b["speedup"] / TOLERANCE
        ok = cur_speedup >= floor
        if not ok:
            failures.append(
                f"{name}: speedup {cur_speedup:.2f} < floor {floor:.2f} "
                f"(baseline {b['speedup']:.2f})"
            )
        print(
            f"{name:<14} {b['speedup']:7.2f} {floor:7.2f} {cur_speedup:8.2f} "
            f"{fmt_ms(c, 'naive_median_ms'):>9} {fmt_ms(c, 'median_ms'):>10}  "
            f"{'ok' if ok else 'REGRESSED'}"
        )

    extras = sorted(set(curr) - set(REQUIRED))
    if extras:
        print("\nungated pairs (informational):")
        for name in extras:
            c = curr[name]
            print(f"  {name:<20} speedup {c['speedup']:6.2f}")

    if failures:
        print("\nbench gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        print(
            "If this regression is intentional, refresh BENCH_main.json "
            "from this run's bench-ci artifact (see rust/benches/perf.rs)."
        )
        sys.exit(1)
    print("\nbench gate passed")


if __name__ == "__main__":
    main()
