"""L1 Pallas kernel: Gaussian kernel-column block generator.

This is the compute hot spot of oASIS when run over a raw dataset: given a
block of data points Z_blk (n, m) and the currently selected points
Z_sel (k, m), emit the (n, k) block of kernel columns

    C[i, j] = exp(-||z_i - s_j||^2 / sigma^2).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid walks the n axis;
each grid step holds a (block_n, m) slab of Z and the full (k, m) selected
set in VMEM and performs an MXU-shaped contraction Z_blk @ Z_sel^T followed
by VPU elementwise exp. On this image Pallas runs interpret=True (CPU PJRT
cannot execute Mosaic custom-calls); the lowered HLO is what the Rust
runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gaussian_kernel(z_ref, s_ref, g_ref, o_ref):
    """One grid step: (block_n, m) x (k, m) -> (block_n, k)."""
    z = z_ref[...]                       # (block_n, m)
    s = s_ref[...]                       # (k, m)
    inv_sigma_sq = g_ref[0, 0]
    x2 = jnp.sum(z * z, axis=1, keepdims=True)                  # (block_n, 1)
    y2 = jnp.sum(s * s, axis=1, keepdims=True).T                # (1, k)
    xy = jnp.dot(z, s.T, preferred_element_type=jnp.float32)    # (block_n, k)
    sq = jnp.maximum(x2 + y2 - 2.0 * xy, 0.0)
    o_ref[...] = jnp.exp(-sq * inv_sigma_sq)


def _linear_kernel(z_ref, s_ref, o_ref):
    """One grid step of the Gram-matrix variant: plain inner products."""
    o_ref[...] = jnp.dot(
        z_ref[...], s_ref[...].T, preferred_element_type=jnp.float32
    )


def _pick_block(n: int, target: int = 256) -> int:
    """Largest divisor of n that is <= target (grid must tile n exactly)."""
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_n",))
def gaussian_block(z_blk, z_sel, inv_sigma_sq, *, block_n: int = 256):
    """Gaussian kernel columns via the Pallas kernel.

    Args:
      z_blk: (n, m) float32 data block.
      z_sel: (k, m) float32 selected points.
      inv_sigma_sq: scalar 1/sigma^2 (traced; passed as a (1, 1) operand).
      block_n: tile size along n; must divide n (adjusted by caller).

    Returns:
      (n, k) float32 kernel-column block.
    """
    n, m = z_blk.shape
    k, _ = z_sel.shape
    bn = _pick_block(n, block_n)
    gamma = jnp.asarray(inv_sigma_sq, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _gaussian_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((k, m), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=True,
    )(z_blk, z_sel, gamma)


@functools.partial(jax.jit, static_argnames=("block_n",))
def linear_block(z_blk, z_sel, *, block_n: int = 256):
    """Linear (Gram) kernel columns via the Pallas kernel: Z_blk @ Z_sel^T."""
    n, m = z_blk.shape
    k, _ = z_sel.shape
    bn = _pick_block(n, block_n)
    return pl.pallas_call(
        _linear_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((k, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=True,
    )(z_blk, z_sel)
