"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match the corresponding function here to float32 tolerance. The pytest
suite (python/tests/) sweeps shapes and dtypes with hypothesis and asserts
allclose against these implementations.
"""

import jax.numpy as jnp


def gaussian_block_ref(z_blk, z_sel, inv_sigma_sq):
    """Gaussian kernel columns, reference implementation.

    Args:
      z_blk: (n, m) block of data points (row-major points).
      z_sel: (k, m) selected data points.
      inv_sigma_sq: scalar, 1/sigma^2.

    Returns:
      (n, k) block of the kernel matrix: exp(-||z_i - z_j||^2 / sigma^2).
    """
    x2 = jnp.sum(z_blk * z_blk, axis=1, keepdims=True)          # (n, 1)
    y2 = jnp.sum(z_sel * z_sel, axis=1, keepdims=True).T        # (1, k)
    xy = z_blk @ z_sel.T                                        # (n, k)
    sq = jnp.maximum(x2 + y2 - 2.0 * xy, 0.0)
    return jnp.exp(-sq * inv_sigma_sq)


def linear_block_ref(z_blk, z_sel):
    """Linear (Gram) kernel columns: G(i, j) = z_i^T z_j."""
    return z_blk @ z_sel.T


def delta_scores_ref(c, r, d):
    """oASIS selection scores, reference implementation.

    Delta_i = d_i - sum_k C(i, k) * R(k, i)   (= d - colsum(C o R) in the
    paper's notation, where R = W^{-1} C^T).

    Args:
      c: (n, l) sampled columns (zero-padded beyond the current k).
      r: (l, n) R matrix (zero-padded beyond the current k).
      d: (n,) diagonal of G.

    Returns:
      (n,) vector of Schur complements Delta.
    """
    return d - jnp.sum(c * r.T, axis=1)


def rank1_r_update_ref(r, q, c_row, c_new, s):
    """Rank-1 update of R (Eq. 6 of the paper), reference implementation.

    Given R_k (l, n) with the first k rows live, q = R[:, i] (zero-padded
    to l), the projected row ``c_row = q^T C^T`` (n,), the new column
    c_new (n,) and the inverse Schur complement s, produce

        R_top = R + s * q (q^T C^T - c_new^T)        # updated live rows
        r_new = s * (c_new^T - q^T C^T)              # the appended row

    Returns (R_top, r_new).
    """
    diff = c_row - c_new                                        # (n,)
    r_top = r + s * jnp.outer(q, diff)
    r_new = -s * diff
    return r_top, r_new
