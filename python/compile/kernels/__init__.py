"""L1 Pallas kernels for the oASIS hot spots (build-time only)."""

from .delta import delta_scores, rank1_r_update
from .gaussian import gaussian_block, linear_block

__all__ = [
    "delta_scores",
    "rank1_r_update",
    "gaussian_block",
    "linear_block",
]
