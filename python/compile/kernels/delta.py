"""L1 Pallas kernel: oASIS Delta-score computation.

The per-iteration scoring step of oASIS (Alg. 1 of the paper):

    Delta = d - colsum(C o R)      i.e.  Delta_i = d_i - sum_k C(i,k) R(k,i)

C is (n, l) and R is (l, n) where l is the *maximum* number of sampled
columns; rows/columns beyond the current k are zero-padded, which leaves
Delta unchanged (zero contributions). This padding trick is what lets the
Rust runtime reuse one fixed-shape AOT artifact for every iteration.

TPU mapping: pure VPU reduction, tiled along n; each grid step holds a
(block_n, l) tile of C and the matching (l, block_n) tile of R in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _delta_kernel(c_ref, r_ref, d_ref, o_ref):
    """One grid step: Delta tile = d tile - row-dot(C tile, R tile^T)."""
    c = c_ref[...]                       # (block_n, l)
    r = r_ref[...]                       # (l, block_n)
    d = d_ref[...]                       # (block_n,)
    o_ref[...] = d - jnp.sum(c * r.T, axis=1)


def _pick_block(n: int, target: int = 512) -> int:
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_n",))
def delta_scores(c, r, d, *, block_n: int = 512):
    """oASIS selection scores via the Pallas kernel.

    Args:
      c: (n, l) float32 sampled columns, zero-padded beyond current k.
      r: (l, n) float32 R = W^{-1} C^T, zero-padded beyond current k.
      d: (n,) float32 diagonal of G.

    Returns:
      (n,) float32 vector of Schur complements.
    """
    n, l = c.shape
    bn = _pick_block(n, block_n)
    return pl.pallas_call(
        _delta_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, l), lambda i: (i, 0)),
            pl.BlockSpec((l, bn), lambda i: (0, i)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(c, r, d)


def _rank1_kernel(r_ref, q_ref, diff_ref, s_ref, o_ref):
    """One grid step of the Eq. 6 rank-1 update: R += s * q diff^T."""
    r = r_ref[...]                       # (l, block_n)
    q = q_ref[...]                       # (l, 1)
    diff = diff_ref[...]                 # (1, block_n)
    s = s_ref[0, 0]
    o_ref[...] = r + s * (q * diff)


@functools.partial(jax.jit, static_argnames=("block_n",))
def rank1_r_update(r, q, diff, s, *, block_n: int = 512):
    """Rank-1 update of the live block of R (Eq. 6): R + s * outer(q, diff).

    Args:
      r: (l, n) float32 R matrix (live rows in the top-k block).
      q: (l,) float32 q = R[:, i] zero-padded to l.
      diff: (n,) float32 q^T C^T - c_new^T.
      s: scalar 1/Delta(i).

    Returns:
      (l, n) float32 updated R. The appended row, s * (-diff), is formed by
      the caller (it is a cheap scale).
    """
    l, n = r.shape
    bn = _pick_block(n, block_n)
    q2 = q.reshape(l, 1)
    diff2 = diff.reshape(1, n)
    s2 = jnp.asarray(s, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _rank1_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((l, bn), lambda i: (0, i)),
            pl.BlockSpec((l, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, bn), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((l, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((l, n), jnp.float32),
        interpret=True,
    )(r, q2, diff2, s2)
