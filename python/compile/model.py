"""L2: the oASIS per-iteration compute graph in JAX, calling L1 kernels.

Each public function here is a jit-able graph that composes the Pallas
kernels in ``kernels/``. ``aot.py`` lowers fixed-shape instances of these
functions to HLO text, which the Rust runtime (rust/src/runtime/) loads and
executes via PJRT. Python never runs on the request path.

Padding convention (shared with the Rust side): all artifacts are lowered at
a maximum column budget ``l``; C is (n, l), R is (l, n) and entries at
indices >= current k are zero, which leaves every result below unchanged.
"""

import jax
import jax.numpy as jnp

from .kernels import delta_scores, gaussian_block, linear_block, rank1_r_update


def score_columns(c, r, d, mask):
    """Masked oASIS scores: Delta with already-selected entries suppressed.

    Args:
      c: (n, l) sampled columns, zero-padded.
      r: (l, n) R = W^{-1} C^T, zero-padded.
      d: (n,) diag(G).
      mask: (n,) float32, 0.0 at already-selected indices, 1.0 elsewhere.

    Returns:
      (delta, masked_abs): the raw Schur complements (n,) and |Delta| with
      selected entries forced to -1 so argmax never picks them.
    """
    delta = delta_scores(c, r, d)
    masked = jnp.where(mask > 0.5, jnp.abs(delta), -1.0)
    return delta, masked


def score_and_select(c, r, d, mask):
    """Fused scoring + argmax: returns (delta, best_index, best_abs_delta)."""
    delta, masked = score_columns(c, r, d, mask)
    idx = jnp.argmax(masked)
    return delta, idx.astype(jnp.int32), masked[idx]


def gaussian_columns(z_blk, z_sel, inv_sigma_sq):
    """Kernel-column block for the Gaussian kernel (L1 kernel pass-through)."""
    return gaussian_block(z_blk, z_sel, inv_sigma_sq)


def gram_columns(z_blk, z_sel):
    """Kernel-column block for the linear/Gram kernel."""
    return linear_block(z_blk, z_sel)


def update_r(r, q, c_row, c_new, s):
    """Eq. 6: rank-1 update of R's live block plus the appended row.

    Args:
      r: (l, n) R matrix.
      q: (l,) q = R[:, i] (zero-padded).
      c_row: (n,) q^T C^T.
      c_new: (n,) the newly sampled column of G.
      s: scalar 1/Delta(i).

    Returns:
      (r_top, r_new): updated (l, n) live block and the (n,) appended row.
      The caller writes ``r_new`` into row k of the padded R buffer.
    """
    diff = c_row - c_new
    r_top = rank1_r_update(r, q, diff, s)
    r_new = -s * diff
    return r_top, r_new


def oasis_iteration(c, r, d, mask, z, inv_sigma_sq):
    """A fully fused oASIS iteration body (score -> select -> new column).

    Used for the L2-fusion ablation: selects the next index and computes its
    kernel column in one lowered module, avoiding a host round-trip between
    scoring and column generation.

    Args:
      c, r, d, mask: as in ``score_and_select``.
      z: (n, m) the full (or shard-local) dataset block.
      inv_sigma_sq: Gaussian kernel scale.

    Returns:
      (delta, idx, col): scores, selected index, and the selected point's
      kernel column against the entire block z (n,).
    """
    delta, idx, _ = score_and_select(c, r, d, mask)
    zi = jax.lax.dynamic_slice_in_dim(z, idx, 1, axis=0)        # (1, m)
    col = gaussian_block(z, zi, inv_sigma_sq)[:, 0]             # (n,)
    return delta, idx, col
