"""AOT lowering: JAX/Pallas (L2+L1) -> HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the vendored xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Each artifact is a fixed-shape lowering of a function in ``model.py``. The
manifest (artifacts/manifest.json) tells the Rust runtime which shapes exist;
off-manifest shapes fall back to the native Rust path.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifact_set():
    """The fixed-shape artifact registry.

    Keep this list in sync with rust/src/runtime/artifacts.rs expectations:
    every entry becomes ``<name>.hlo.txt`` plus a manifest row.
    """
    arts = []

    # Delta scoring: the per-iteration hot spot. l (max columns) = 512,
    # zero-padded; n swept over the bucket sizes the Rust side pads to.
    for n in (1024, 2048, 4096, 8192):
        l = 512
        arts.append(
            dict(
                name=f"delta_n{n}_l{l}",
                op="delta_scores",
                fn=lambda c, r, d: (model.delta_scores(c, r, d),),
                args=[spec(n, l), spec(l, n), spec(n)],
                dims=dict(n=n, l=l),
                inputs=["c", "r", "d"],
                outputs=["delta"],
            )
        )

    # Fused score+select (returns delta, argmax index, best |delta|).
    for n in (2048, 4096):
        l = 512
        arts.append(
            dict(
                name=f"score_select_n{n}_l{l}",
                op="score_and_select",
                fn=model.score_and_select,
                args=[spec(n, l), spec(l, n), spec(n), spec(n)],
                dims=dict(n=n, l=l),
                inputs=["c", "r", "d", "mask"],
                outputs=["delta", "idx", "best"],
            )
        )

    # Gaussian kernel-column blocks: k (selected budget) = 512, m = 16
    # (data dims are zero-padded up to 16; larger m uses native fallback).
    for n in (1024, 4096):
        k, m = 512, 16
        arts.append(
            dict(
                name=f"gauss_n{n}_k{k}_m{m}",
                op="gaussian_columns",
                fn=lambda z, s, g: (model.gaussian_columns(z, s, g),),
                args=[spec(n, m), spec(k, m), spec()],
                dims=dict(n=n, k=k, m=m),
                inputs=["z_blk", "z_sel", "inv_sigma_sq"],
                outputs=["cols"],
            )
        )

    # Rank-1 R update (Eq. 6) at the common bucket.
    n, l = 4096, 512
    arts.append(
        dict(
            name=f"update_r_n{n}_l{l}",
            op="update_r",
            fn=model.update_r,
            args=[spec(l, n), spec(l), spec(n), spec(n), spec()],
            dims=dict(n=n, l=l),
            inputs=["r", "q", "c_row", "c_new", "s"],
            outputs=["r_top", "r_new"],
        )
    )

    # Fully fused iteration (L2-fusion ablation).
    n, l, m = 4096, 512, 16
    arts.append(
        dict(
            name=f"iteration_n{n}_l{l}_m{m}",
            op="oasis_iteration",
            fn=model.oasis_iteration,
            args=[spec(n, l), spec(l, n), spec(n), spec(n), spec(n, m), spec()],
            dims=dict(n=n, l=l, m=m),
            inputs=["c", "r", "d", "mask", "z", "inv_sigma_sq"],
            outputs=["delta", "idx", "col"],
        )
    )
    return arts


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower only this artifact")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for art in artifact_set():
        if args.only and art["name"] != args.only:
            continue
        lowered = jax.jit(art["fn"]).lower(*art["args"])
        text = to_hlo_text(lowered)
        fname = f"{art['name']}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(
            dict(
                name=art["name"],
                file=fname,
                op=art["op"],
                dims=art["dims"],
                inputs=[
                    dict(name=nm, shape=list(a.shape), dtype=str(a.dtype))
                    for nm, a in zip(art["inputs"], art["args"])
                ],
                outputs=art["outputs"],
            )
        )
        print(f"lowered {art['name']:28s} -> {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(dict(version=1, artifacts=manifest), f, indent=1)
    print(f"wrote {mpath} ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
