"""Build-time compile path: JAX/Pallas -> AOT HLO artifacts for Rust/PJRT."""
