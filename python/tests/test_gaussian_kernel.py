"""L1 Gaussian / linear kernel-column Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (n, k, m), block sizes, and data scales; every case
asserts allclose against ref.py. This is the core correctness signal for the
kernel-column hot path.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gaussian_block, linear_block
from compile.kernels.ref import gaussian_block_ref, linear_block_ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _data(seed, n, k, m, scale=1.0):
    rng = np.random.default_rng(seed)
    z = (rng.normal(size=(n, m)) * scale).astype(np.float32)
    s = (rng.normal(size=(k, m)) * scale).astype(np.float32)
    return z, s


@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 96),
    k=st.integers(1, 48),
    m=st.integers(1, 24),
    gamma=st.floats(1e-3, 10.0),
)
def test_gaussian_matches_ref(seed, n, k, m, gamma):
    z, s = _data(seed, n, k, m)
    got = gaussian_block(z, s, np.float32(gamma))
    want = gaussian_block_ref(jnp.array(z), jnp.array(s), np.float32(gamma))
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-5, atol=1e-6)


@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 96),
    k=st.integers(1, 48),
    m=st.integers(1, 24),
)
def test_linear_matches_ref(seed, n, k, m):
    z, s = _data(seed, n, k, m)
    got = linear_block(z, s)
    want = linear_block_ref(jnp.array(z), jnp.array(s))
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("block_n", [1, 2, 8, 32, 64])
def test_gaussian_block_size_invariance(block_n):
    """Result must not depend on the grid tiling."""
    z, s = _data(7, 64, 9, 4)
    base = gaussian_block(z, s, np.float32(0.5), block_n=64)
    tiled = gaussian_block(z, s, np.float32(0.5), block_n=block_n)
    np.testing.assert_allclose(np.array(base), np.array(tiled), rtol=1e-6)


def test_gaussian_diagonal_is_one():
    """k(z, z) = exp(0) = 1 for the Gaussian kernel."""
    z, _ = _data(3, 17, 1, 6)
    out = np.array(gaussian_block(z, z, np.float32(2.0)))
    np.testing.assert_allclose(np.diag(out), 1.0, atol=1e-5)


def test_gaussian_symmetry():
    """K(A, B) == K(B, A)^T."""
    z, s = _data(11, 20, 20, 5)
    ab = np.array(gaussian_block(z, s, np.float32(1.3)))
    ba = np.array(gaussian_block(s, z, np.float32(1.3)))
    np.testing.assert_allclose(ab, ba.T, rtol=1e-6)


def test_gaussian_range():
    """Gaussian kernel values always lie in [0, 1] (0 via f32 underflow)."""
    z, s = _data(13, 40, 13, 3, scale=5.0)
    out = np.array(gaussian_block(z, s, np.float32(0.7)))
    assert np.all(out >= 0.0) and np.all(out <= 1.0 + 1e-5)


def test_gaussian_zero_pad_m_invariance():
    """Zero-padding the feature dim must not change the kernel values

    (the padding trick the Rust runtime relies on for the m=16 artifacts)."""
    z, s = _data(17, 32, 8, 5)
    base = np.array(gaussian_block(z, s, np.float32(0.9)))
    zp = np.zeros((32, 16), np.float32)
    zp[:, :5] = z
    sp = np.zeros((8, 16), np.float32)
    sp[:, :5] = s
    padded = np.array(gaussian_block(zp, sp, np.float32(0.9)))
    np.testing.assert_allclose(base, padded, rtol=1e-4, atol=1e-7)


def test_gaussian_large_distance_underflow_safe():
    """Far-apart points give ~0, never NaN/Inf."""
    z = np.full((4, 3), 1e3, np.float32)
    s = np.full((2, 3), -1e3, np.float32)
    out = np.array(gaussian_block(z, s, np.float32(1.0)))
    assert np.all(np.isfinite(out)) and np.all(out == 0.0)
