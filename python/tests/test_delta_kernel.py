"""L1 Delta-score and rank-1 R-update Pallas kernels vs the pure-jnp oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import delta_scores, rank1_r_update
from compile.kernels.ref import delta_scores_ref, rank1_r_update_ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _crd(seed, n, l):
    rng = np.random.default_rng(seed)
    c = rng.normal(size=(n, l)).astype(np.float32)
    r = rng.normal(size=(l, n)).astype(np.float32)
    d = rng.normal(size=(n,)).astype(np.float32)
    return c, r, d


@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 128),
    l=st.integers(1, 64),
)
def test_delta_matches_ref(seed, n, l):
    c, r, d = _crd(seed, n, l)
    got = np.array(delta_scores(c, r, d))
    want = np.array(delta_scores_ref(c, r, d))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_delta_zero_pad_invariance():
    """Zero-padded (inactive) columns of C / rows of R leave Delta unchanged

    — the padding contract the fixed-shape AOT artifacts depend on."""
    c, r, d = _crd(5, 48, 12)
    base = np.array(delta_scores(c, r, d))
    cp = np.zeros((48, 32), np.float32)
    cp[:, :12] = c
    rp = np.zeros((32, 48), np.float32)
    rp[:12, :] = r
    padded = np.array(delta_scores(cp, rp, d))
    np.testing.assert_allclose(base, padded, rtol=1e-5, atol=1e-5)


def test_delta_exact_on_psd():
    """For G = X^T X with Lambda = all columns, Delta must vanish.

    R = W^{-1} C^T with C = G, W = G (full sampling) gives
    Delta_i = d_i - (C R)_ii = d_i - G_ii = 0.
    """
    rng = np.random.default_rng(9)
    x = rng.normal(size=(6, 20)).astype(np.float64)
    g = (x.T @ x).astype(np.float64)
    w_inv = np.linalg.pinv(g)
    r = (w_inv @ g.T).astype(np.float32)
    c = g.astype(np.float32)
    d = np.diag(g).astype(np.float32)
    delta = np.array(delta_scores(c, r, d))
    assert np.max(np.abs(delta)) < 1e-2 * np.max(d)


@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 96),
    l=st.integers(1, 48),
    s=st.floats(-3.0, 3.0),
)
def test_rank1_update_matches_ref(seed, n, l, s):
    rng = np.random.default_rng(seed)
    r = rng.normal(size=(l, n)).astype(np.float32)
    q = rng.normal(size=(l,)).astype(np.float32)
    c_row = rng.normal(size=(n,)).astype(np.float32)
    c_new = rng.normal(size=(n,)).astype(np.float32)
    got = np.array(rank1_r_update(r, q, c_row - c_new, np.float32(s)))
    want, _ = rank1_r_update_ref(r, q, c_row, c_new, np.float32(s))
    np.testing.assert_allclose(got, np.array(want), rtol=1e-4, atol=1e-4)


def test_rank1_update_zero_s_identity():
    """s = 0 must leave R untouched."""
    rng = np.random.default_rng(2)
    r = rng.normal(size=(8, 24)).astype(np.float32)
    q = rng.normal(size=(8,)).astype(np.float32)
    diff = rng.normal(size=(24,)).astype(np.float32)
    out = np.array(rank1_r_update(r, q, diff, np.float32(0.0)))
    np.testing.assert_array_equal(out, r)
