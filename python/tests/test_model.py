"""L2 model graph tests: masking, selection, fused iteration, update algebra."""

import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels.ref import gaussian_block_ref


def _state(seed=0, n=48, l=16, m=6):
    rng = np.random.default_rng(seed)
    z = rng.normal(size=(n, m)).astype(np.float32)
    c = rng.normal(size=(n, l)).astype(np.float32)
    r = rng.normal(size=(l, n)).astype(np.float32)
    d = rng.normal(size=(n,)).astype(np.float32)
    return z, c, r, d


def test_score_columns_masks_selected():
    z, c, r, d = _state()
    mask = np.ones(48, np.float32)
    mask[[3, 7, 11]] = 0.0
    delta, masked = model.score_columns(c, r, d, mask)
    masked = np.array(masked)
    assert np.all(masked[[3, 7, 11]] == -1.0)
    live = np.delete(np.arange(48), [3, 7, 11])
    np.testing.assert_allclose(
        masked[live], np.abs(np.array(delta))[live], rtol=1e-6
    )


def test_score_and_select_argmax_consistent():
    z, c, r, d = _state(seed=4)
    mask = np.ones(48, np.float32)
    mask[:10] = 0.0
    delta, idx, best = model.score_and_select(c, r, d, mask)
    delta = np.array(delta)
    idx = int(idx)
    assert idx >= 10
    expected = 10 + int(np.argmax(np.abs(delta[10:])))
    assert idx == expected
    np.testing.assert_allclose(float(best), abs(delta[idx]), rtol=1e-6)


def test_score_and_select_never_picks_masked_even_if_larger():
    """A huge |Delta| at a masked index must be ignored."""
    z, c, r, d = _state(seed=5)
    d = d.copy()
    d[0] = 1e6  # makes Delta_0 enormous
    mask = np.ones(48, np.float32)
    mask[0] = 0.0
    _, idx, _ = model.score_and_select(c, r, d, mask)
    assert int(idx) != 0


def test_oasis_iteration_column_matches_ref():
    """The fused iteration's kernel column equals the oracle column."""
    z, c, r, d = _state(seed=8)
    mask = np.ones(48, np.float32)
    gamma = np.float32(0.4)
    delta, idx, col = model.oasis_iteration(c, r, d, mask, z, gamma)
    idx = int(idx)
    want = gaussian_block_ref(jnp.array(z), jnp.array(z[idx : idx + 1]), gamma)
    np.testing.assert_allclose(
        np.array(col), np.array(want)[:, 0], rtol=1e-5, atol=1e-6
    )


def test_update_r_reproduces_direct_inverse():
    """Iterating Eq. 5/6 from k columns to k+1 must equal recomputing
    R = W^{-1} C^T from scratch (the paper's central algebraic identity)."""
    rng = np.random.default_rng(12)
    x = rng.normal(size=(8, 30))
    g = x.T @ x + 1e-6 * np.eye(30)
    lam = [4, 9, 17]  # already selected
    new = 22          # next selection
    c_k = g[:, lam]                                     # (30, 3)
    w_k = g[np.ix_(lam, lam)]
    w_inv = np.linalg.inv(w_k)
    r_k = w_inv @ c_k.T                                 # (3, 30)

    b = g[lam, new]
    dd = g[new, new]
    delta = dd - b @ w_inv @ b
    s = 1.0 / delta
    q = w_inv @ b                                       # = R[:, new] indeed
    np.testing.assert_allclose(q, r_k[:, new], rtol=1e-8)

    c_new = g[:, new]
    c_row = q @ c_k.T                                   # q^T C^T
    r_top, r_new = model.update_r(
        r_k.astype(np.float32),
        q.astype(np.float32),
        c_row.astype(np.float32),
        c_new.astype(np.float32),
        np.float32(s),
    )
    lam2 = lam + [new]
    w2_inv = np.linalg.inv(g[np.ix_(lam2, lam2)])
    r2 = w2_inv @ g[:, lam2].T                          # (4, 30)
    np.testing.assert_allclose(np.array(r_top), r2[:3], rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.array(r_new), r2[3], rtol=1e-3, atol=1e-4)
