"""AOT pipeline tests: lowering produces loadable HLO text + sane manifest."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_artifact_set_well_formed():
    arts = aot.artifact_set()
    names = [a["name"] for a in arts]
    assert len(names) == len(set(names)), "artifact names must be unique"
    for a in arts:
        assert len(a["inputs"]) == len(a["args"])
        assert a["op"] in (
            "delta_scores",
            "score_and_select",
            "gaussian_columns",
            "update_r",
            "oasis_iteration",
        )
        for dim, v in a["dims"].items():
            assert v > 0


def test_hlo_text_is_parseable_hlo():
    """Lower the smallest delta artifact and sanity-check the HLO text."""
    arts = [a for a in aot.artifact_set() if a["name"] == "delta_n1024_l512"]
    lowered = jax.jit(arts[0]["fn"]).lower(*arts[0]["args"])
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # fixed shapes must appear in the program shape
    assert "f32[1024,512]" in text and "f32[512,1024]" in text


def test_lowered_delta_executes_and_matches(tmp_path):
    """Round-trip: lowered HLO executed via jax equals the eager result."""
    n, l = 1024, 512
    rng = np.random.default_rng(0)
    c = rng.normal(size=(n, l)).astype(np.float32)
    r = rng.normal(size=(l, n)).astype(np.float32)
    d = rng.normal(size=(n,)).astype(np.float32)
    fn = jax.jit(lambda c, r, d: (model.delta_scores(c, r, d),))
    compiled = fn.lower(
        jax.ShapeDtypeStruct((n, l), jnp.float32),
        jax.ShapeDtypeStruct((l, n), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
    ).compile()
    out = compiled(c, r, d)[0]
    eager = model.delta_scores(c, r, d)
    np.testing.assert_allclose(np.array(out), np.array(eager), rtol=1e-5)


def test_aot_cli_writes_manifest(tmp_path):
    """The module CLI lowers --only one artifact and emits a valid manifest."""
    out = tmp_path / "arts"
    env = dict(os.environ)
    proc = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--only",
            "delta_n1024_l512",
        ],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
        env=env,
    )
    assert proc.returncode == 0, proc.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["version"] == 1
    assert len(manifest["artifacts"]) == 1
    art = manifest["artifacts"][0]
    assert art["name"] == "delta_n1024_l512"
    assert (out / art["file"]).exists()
    assert art["inputs"][0]["shape"] == [1024, 512]
